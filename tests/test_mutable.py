"""Streaming mutability: LSM delta + tombstones + background merge.

Covers the visibility invariants (a deleted id is never returned; an
upserted id is served with its new vector/attrs) across codecs × backends
× predicate kinds, both before and after the merge folds the delta into
the main index; the no-write fast path's bit-exactness; the incremental
HELP re-link; the compaction policy's cost gate; the serve-layer write
path (write admission, read-your-writes, background merge scheduling);
and the end-to-end freshness bar (Recall@10 ≥ 0.9 vs the post-write brute
oracle, pre and post merge, through the serving stack).
"""
import dataclasses
import time

import numpy as np
import pytest

from repro.api import (
    ANY, BETWEEN, MATCH, ONE_OF, Engine, Query, QueryBatch, SearchParams,
)
from repro.api.planner import CostModel
from repro.core import help_graph as help_mod
from repro.core.baselines import brute_force_hybrid, recall_at_k
from repro.core.graph_ops import INVALID
from repro.core.help_graph import HelpConfig
from repro.data.synthetic import make_hybrid_dataset
from repro.mutable import CompactionPolicy, DeltaSegment, MutableEngine
from repro.quant import QuantConfig
from repro.quant.pq import pq_encode
from repro.quant.sq import sq8_encode
from repro.serve import (
    Delete, Request, TenantPolicy, TenantRegistry, ThreadedServer, Upsert,
    serve_loop,
)

N0 = 900  # rows in the frozen main build; 60 more stream in as writes
CFG = HelpConfig(gamma=8, gamma_new=4, max_rounds=2,
                 quality_sample=32, node_block=256)
K, POOL = 10, 128


@pytest.fixture(scope="module")
def ds():
    return make_hybrid_dataset(
        n=N0 + 60, n_queries=32, profile="sift", attr_dim=5,
        labels_per_dim=3, n_clusters=8, attr_cluster_corr=0.6, seed=0,
    )


@pytest.fixture(scope="module")
def base_indexes(ds):
    """One frozen StableIndex per codec; engines are derived per test so
    merges (which swap an engine's index pointer) never leak across."""
    out = {}
    for mode in ("none", "sq8", "pq"):
        out[mode] = Engine.build(
            ds.features[:N0], ds.attrs[:N0], CFG,
            quant_cfg=QuantConfig(mode=mode, pq_subspaces=16),
        ).index
    return out


def _engine(base_indexes, mode) -> Engine:
    # shallow copy: merge replaces the .index reference, never its arrays
    return Engine(dataclasses.replace(base_indexes[mode]))


def _apply_script(m: MutableEngine, ds):
    """The shared write script: 40 inserts, 10 attr+vector overwrites,
    15 deletes. Returns (inserted ids, {id: (vec, attrs)} overwrites,
    deleted ids)."""
    inserted = list(range(N0, N0 + 40))
    for i in inserted:
        m.upsert(ds.features[i], ds.attrs[i], id=i)
    rng = np.random.default_rng(3)
    over = sorted(int(x) for x in rng.choice(N0, 10, replace=False))
    overwrites = {}
    for i in over:
        v = (ds.features[i]
             + 0.05 * rng.standard_normal(ds.features.shape[1])
             ).astype(np.float32)
        a = ((ds.attrs[i] + 1) % 3).astype(np.int32)
        m.upsert(v, a, id=i)
        overwrites[i] = (v, a)
    candidates = np.setdiff1d(np.arange(N0), np.asarray(over))
    deleted = sorted(int(x) for x in rng.choice(candidates, 15,
                                                replace=False))
    for i in deleted:
        assert m.delete(i)
    return inserted, overwrites, deleted


def _current_attrs(ds, overwrites):
    attrs = ds.attrs[:N0 + 60].copy()
    for i, (_, a) in overwrites.items():
        attrs[i] = a
    return attrs


@pytest.fixture(scope="module")
def written(base_indexes, ds):
    """Pre-merge state per codec (the delta holds every write)."""
    out = {}
    for mode in base_indexes:
        m = MutableEngine(_engine(base_indexes, mode),
                          CompactionPolicy(max_delta_rows=10 ** 9))
        out[mode] = (m, _apply_script(m, ds))
    return out


@pytest.fixture(scope="module")
def merged(base_indexes, ds):
    """Post-merge state per codec (independent engines; the `written`
    fixture's pre-merge state stays untouched)."""
    out = {}
    for mode in base_indexes:
        m = MutableEngine(_engine(base_indexes, mode),
                          CompactionPolicy(max_delta_rows=10 ** 9))
        script = _apply_script(m, ds)
        stats = m.merge()
        assert stats is not None and stats["linked"] == 50
        assert m.delta.n_rows == 0 and not m.oplog
        out[mode] = (m, script)
    return out


# ---------------------------------------------------------------------------
# DeltaSegment
# ---------------------------------------------------------------------------


class TestDeltaSegment:
    def test_append_overwrite_kill(self):
        d = DeltaSegment(4, 2)
        r0 = d.append(7, np.ones(4), np.zeros(2))
        assert d.n_alive == 1 and d.row_of[7] == r0
        r1 = d.append(7, 2 * np.ones(4), np.ones(2))  # overwrite: new row
        assert r1 != r0 and d.n_alive == 1 and d.n_rows == 2
        assert not d.alive[r0] and d.alive[r1]
        latest = d.latest()
        np.testing.assert_array_equal(latest[7][0], 2 * np.ones(4))
        assert latest[7][2] is True
        assert d.kill(7) and d.n_alive == 0
        assert not d.kill(7)  # already dead
        assert d.latest()[7][2] is False  # dead latest row kept for merge

    def test_capacity_doubles(self):
        d = DeltaSegment(2, 1)
        for i in range(600):
            d.append(i, np.zeros(2), np.zeros(1))
        assert d.n_rows == 600 and d.features.shape[0] == 1024

    def test_topk_padding_and_dead_masking(self, ds):
        d = DeltaSegment(ds.features.shape[1], ds.attrs.shape[1])
        d.append(1, ds.features[1], ds.attrs[1])
        d.append(2, ds.features[2], ds.attrs[2])
        d.kill(2)
        qb = QueryBatch.match(ds.features[1:2], ds.attrs[1:2])
        ids, sq = d.topk(qb, 5, None, oracle=True)
        assert ids.shape == (1, 5)
        assert ids[0, 0] == 1  # the only alive row
        assert (ids[0, 1:] == INVALID).all()  # dead + padding masked out


# ---------------------------------------------------------------------------
# CompactionPolicy
# ---------------------------------------------------------------------------


class TestCompactionPolicy:
    def test_size_trigger(self):
        pol = CompactionPolicy(max_delta_rows=100)
        assert not pol.should_merge(delta_rows=99, n_main=10_000)
        assert pol.should_merge(delta_rows=100, n_main=10_000)
        assert not pol.should_merge(delta_rows=0, n_main=10_000)

    def test_cost_gate(self):
        cm = CostModel(unit_evals=16.0, probe_pool=64, probe_n=10_000,
                       brute_eval_cost=1.0, batch_overhead=4.0)
        pol = CompactionPolicy(max_delta_rows=10 ** 9, min_delta_rows=64,
                               max_cost_regression=0.25, probe_pool=64)
        # below min_delta_rows the cost gate never fires
        assert not pol.should_merge(delta_rows=63, n_main=10_000,
                                    cost_model=cm)
        # a tiny delta is cheaper than 25% of the main traversal
        assert not pol.should_merge(delta_rows=64, n_main=10 ** 6,
                                    cost_model=cm)
        # a huge delta on a small main crosses the regression threshold
        assert pol.should_merge(delta_rows=4000, n_main=5000, cost_model=cm)
        # monotone: merging pressure only grows with delta size
        fired = [pol.should_merge(delta_rows=r, n_main=20_000, cost_model=cm)
                 for r in (64, 512, 4096, 32768)]
        assert fired == sorted(fired)


# ---------------------------------------------------------------------------
# apply_rows + link_nodes (the incremental merge primitives)
# ---------------------------------------------------------------------------


class TestApplyRows:
    @pytest.mark.parametrize("mode", ["none", "sq8", "pq"])
    def test_grow_scatter_and_codes(self, base_indexes, ds, mode):
        idx = base_indexes[mode]
        ids = np.array([5, N0, N0 + 3])  # one overwrite + two new (one gap)
        feats = ds.features[[5, N0, N0 + 3]] + 1.0
        attrs = ds.attrs[[5, N0, N0 + 3]]
        new = idx.apply_rows(ids, feats, attrs)
        assert int(new.features.shape[0]) == N0 + 4
        np.testing.assert_allclose(np.asarray(new.features[ids]), feats,
                                   rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(new.attrs[ids]), attrs)
        # untouched rows bit-identical, grown graph rows INVALID-padded
        np.testing.assert_array_equal(np.asarray(new.features[:5]),
                                      np.asarray(idx.features[:5]))
        assert (np.asarray(new.graph[N0:]) == INVALID).all()
        if mode == "none":
            assert new.quant is None
        else:
            assert int(new.quant.codes.shape[0]) == N0 + 4
            if mode == "sq8":
                want = np.asarray(
                    sq8_encode(feats, idx.quant.sq_params)[0]
                )
            else:
                want = np.asarray(pq_encode(feats, idx.quant.codebook))
            np.testing.assert_array_equal(
                np.asarray(new.quant.codes[ids]), want
            )

    @pytest.mark.parametrize("mode", ["pq4", "opq-pq4"])
    def test_packed_and_rotated_codecs_frozen_through_merge(self, ds, mode):
        """apply_rows / merge must extend packed + rotated codes with the
        *frozen* codec (rotation, codebooks, nibble layout) bit-exactly."""
        eng = Engine.build(
            ds.features[:N0], ds.attrs[:N0], CFG,
            quant_cfg=QuantConfig(mode=mode, pq_subspaces=16,
                                  pq_train_iters=5, opq_iters=2),
        )
        idx = eng.index
        rot_before = (None if idx.quant.rotation is None
                      else np.asarray(idx.quant.rotation).copy())
        m = MutableEngine(eng)
        vec, at = ds.features[N0] + 0.5, ds.attrs[N0]
        nid = m.upsert(vec, at)
        m.merge()
        new = m.engine.index
        # codec state untouched by the merge
        np.testing.assert_array_equal(
            np.asarray(new.quant.codebook.centroids),
            np.asarray(idx.quant.codebook.centroids),
        )
        if rot_before is not None:
            np.testing.assert_array_equal(
                np.asarray(new.quant.rotation), rot_before
            )
        # merged row encoded exactly as the frozen codec encodes it
        want = np.asarray(
            new.quant.encode_rows(vec[None]).astype(new.quant.codes.dtype)
        )[0]
        np.testing.assert_array_equal(np.asarray(new.quant.codes[nid]), want)
        res = m.search((vec[None], at[None]), SearchParams(k=5, quant=mode))
        assert nid in np.asarray(res.ids)[0]

    def test_link_nodes_links_and_bans(self, base_indexes, ds):
        idx = base_indexes["none"]
        ids = np.arange(N0, N0 + 8)
        new = idx.apply_rows(ids, ds.features[N0:N0 + 8], ds.attrs[N0:N0 + 8])
        banned = np.array([3, 11], np.int64)
        graph, repaired = help_mod.link_nodes(
            new.features, new.attrs, new.graph, ids, new.metric_cfg,
            new.help_cfg, banned_ids=banned,
        )
        rows = np.asarray(graph[N0:N0 + 8])
        assert (rows >= 0).any(axis=1).all()  # every new node got edges
        assert not np.isin(rows, banned).any()  # tombstoned ids never linked
        assert repaired > 0  # old nodes absorbed reverse edges
        # repair only rewrites rows, never the graph's shape or id range
        assert graph.shape == new.graph.shape
        assert int(np.asarray(graph).max()) < N0 + 8


# ---------------------------------------------------------------------------
# Federated read: fast path + visibility invariants
# ---------------------------------------------------------------------------


class TestFastPath:
    @pytest.mark.parametrize("mode", ["none", "sq8", "pq"])
    def test_no_write_bit_exact(self, base_indexes, ds, mode):
        eng = _engine(base_indexes, mode)
        m = MutableEngine(eng)
        qb = QueryBatch.match(ds.query_features, ds.query_attrs)
        p = SearchParams(k=K, pool_size=64)
        a, b = eng.search(qb, p), m.search(qb, p)
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
        np.testing.assert_array_equal(np.asarray(a.sqdists),
                                      np.asarray(b.sqdists))


def _check_visibility(m, ds, script, backend):
    inserted, overwrites, deleted = script
    attrs_now = _current_attrs(ds, overwrites)
    p = SearchParams(k=K, pool_size=POOL, backend=backend)

    # a deleted id is never returned — probe with its own exact vector
    probe = deleted[:8]
    qb = QueryBatch.match(ds.features[probe], ds.attrs[probe])
    ids = np.asarray(m.search(qb, p).ids)
    assert not np.isin(ids, np.asarray(deleted)).any()

    # an upserted id is served with its new vector: exact-vector queries
    # must surface it in the top k (rank 0 pre-merge, where the delta scan
    # is exact; membership suffices under quantized main-side scoring)
    some_ins = inserted[:8]
    qb = QueryBatch.match(ds.features[some_ins], ds.attrs[some_ins])
    ids = np.asarray(m.search(qb, p).ids)
    for r, i in enumerate(some_ins):
        assert i in ids[r], (i, ids[r])

    # an overwrite swaps vector AND attrs: the new attrs admit the row
    ov_ids = sorted(overwrites)[:6]
    qv = np.stack([overwrites[i][0] for i in ov_ids])
    qa = np.stack([overwrites[i][1] for i in ov_ids])
    ids = np.asarray(m.search(QueryBatch.match(qv, qa), p).ids)
    for r, i in enumerate(ov_ids):
        assert i in ids[r], (i, ids[r])

    # ONE_OF membership is exact on every backend/codec
    queries = [Query(ds.query_features[i],
                     [MATCH(int(ds.query_attrs[i][0])), ANY,
                      ONE_OF(0, 2), ANY, ANY])
               for i in range(12)]
    res = m.search(QueryBatch.from_queries(queries), p)
    for row in np.asarray(res.ids):
        got = row[row >= 0]
        assert np.isin(attrs_now[got, 2], (0, 2)).all()

    # BETWEEN under enforce_equality: every hit inside the interval
    queries = [Query(ds.query_features[i],
                     [BETWEEN(0, 1), ANY, ANY, ANY,
                      MATCH(int(ds.query_attrs[i][4]))])
               for i in range(12)]
    res = m.search(QueryBatch.from_queries(queries),
                   dataclasses.replace(p, enforce_equality=True))
    for q, row in zip(queries, np.asarray(res.ids)):
        got = row[row >= 0]
        assert (attrs_now[got, 0] <= 1).all()
        assert (attrs_now[got, 4] == q.predicates[4].values[0]).all()


class TestVisibility:
    @pytest.mark.parametrize("mode", ["none", "sq8", "pq"])
    @pytest.mark.parametrize("backend", ["graph", "brute"])
    def test_pre_merge(self, written, ds, mode, backend):
        m, script = written[mode]
        _check_visibility(m, ds, script, backend)

    @pytest.mark.parametrize("mode", ["none", "sq8", "pq"])
    @pytest.mark.parametrize("backend", ["graph", "brute"])
    def test_post_merge(self, merged, ds, mode, backend):
        m, script = merged[mode]
        _check_visibility(m, ds, script, backend)

    def test_logical_n_and_exists(self, written, ds):
        m, (inserted, overwrites, deleted) = written["none"]
        assert m.n_items == N0 + len(inserted) - len(deleted)
        assert all(m.exists(i) for i in inserted)
        assert all(m.exists(i) for i in overwrites)
        assert not any(m.exists(i) for i in deleted)

    def test_merge_preserves_logical_corpus(self, merged, ds):
        m, (inserted, overwrites, deleted) = merged[("none")]
        assert m.n_items == N0 + len(inserted) - len(deleted)
        assert not any(m.exists(i) for i in deleted)  # tombstones persist
        # merged rows hold the post-write values
        i = sorted(overwrites)[0]
        np.testing.assert_allclose(
            np.asarray(m.index.features[i]), overwrites[i][0], rtol=1e-6
        )

    def test_graph_path_parity_with_rebuild(self, merged, written, ds):
        """The incrementally linked graph serves within a whisker of the
        pre-merge federated read (whose delta side is exact) on the same
        logical corpus — the re-link is at parity, not a regression."""
        p = SearchParams(k=K, pool_size=POOL, backend="graph")
        qb = QueryBatch.match(ds.query_features, ds.query_attrs)
        m_pre, (_, overwrites, deleted) = written["none"]
        m_post, _ = merged["none"]
        feats = ds.features[:N0 + 60].copy()
        for i, (v, _) in overwrites.items():
            feats[i] = v
        feats[np.asarray(deleted)] = 1e6
        truth = brute_force_hybrid(
            feats, _current_attrs(ds, overwrites),
            ds.query_features, ds.query_attrs, K,
        )
        r_pre = recall_at_k(np.asarray(m_pre.search(qb, p).ids),
                            truth.ids, K)
        r_post = recall_at_k(np.asarray(m_post.search(qb, p).ids),
                             truth.ids, K)
        assert r_post >= r_pre - 0.05, (r_pre, r_post)


# ---------------------------------------------------------------------------
# Serve-layer write path
# ---------------------------------------------------------------------------


class TestServeWrites:
    def test_write_admission_separate_buckets(self, base_indexes, ds):
        m = MutableEngine(_engine(base_indexes, "none"))
        reg = TenantRegistry(default_policy=TenantPolicy(
            params=SearchParams(k=K, pool_size=64),
            write_rate=1e-9, write_burst=2.0,
        ))
        trace = [(0.0, Upsert("t", ds.features[N0 + i], ds.attrs[N0 + i]))
                 for i in range(5)]
        trace.append((0.0, Request(
            "t", Query(ds.query_features[0],
                       [MATCH(int(v)) for v in ds.query_attrs[0]]))))
        out, stats = serve_loop(m, trace, reg)
        acks = [r for r in out[:5] if r.ok]
        shed = [r for r in out[:5] if not r.ok]
        assert len(acks) == 2 and len(shed) == 3  # burst=2, no refill at t=0
        assert all(r.reason == "write_rate_limit" for r in shed)
        assert out[5].ok  # reads draw from their own (unlimited) bucket
        snap = stats.snapshot()
        assert snap["writes"] == {
            "upserts": 2, "deletes": 0, "shed": 3, "merges": 0,
            "merge_ms_p50": 0.0, "merge_ms_p95": 0.0,
        }
        assert snap["delta"]["delta_rows"] == 2
        assert snap["rejected"] == 0  # write shedding is counted separately

    def test_immutable_engine_rejects_writes(self, base_indexes, ds):
        out, _ = serve_loop(
            _engine(base_indexes, "none"),
            [Upsert("t", ds.features[0], ds.attrs[0])],
        )
        assert not out[0].ok and out[0].reason == "immutable_engine"

    def test_threaded_read_your_writes(self, base_indexes, ds):
        m = MutableEngine(_engine(base_indexes, "none"))
        reg = TenantRegistry(default_policy=TenantPolicy(
            params=SearchParams(k=K, pool_size=POOL)))
        with ThreadedServer(m, reg, window_ms=1.0) as srv:
            i = N0 + 7
            ack = srv.submit(Upsert("t", ds.features[i], ds.attrs[i],
                                    id=i)).result(10)
            assert ack.ok and ack.op == "upsert" and ack.id == i
            q = Query(ds.features[i], [MATCH(int(v)) for v in ds.attrs[i]])
            r = srv.submit(Request("t", q)).result(30)
            assert r.ok and int(r.ids[0]) == i  # fresh row wins at rank 0
            dack = srv.submit(Delete("t", i)).result(10)
            assert dack.ok and dack.applied
            r2 = srv.submit(Request("t", q)).result(30)
            assert r2.ok and i not in np.asarray(r2.ids)
            assert not srv.submit(Delete("t", i)).result(10).applied

    def test_threaded_background_merge(self, base_indexes, ds):
        m = MutableEngine(
            _engine(base_indexes, "none"),
            CompactionPolicy(max_delta_rows=20, min_delta_rows=10 ** 9),
        )
        reg = TenantRegistry(default_policy=TenantPolicy(
            params=SearchParams(k=K, pool_size=64)))
        q = Query(ds.query_features[0],
                  [MATCH(int(v)) for v in ds.query_attrs[0]])
        with ThreadedServer(m, reg, window_ms=1.0) as srv:
            futs = []
            for i in range(40):
                srv.submit(Upsert("t", ds.features[N0 + i % 60],
                                  ds.attrs[N0 + i % 60], id=N0 + i % 60))
                # serving keeps flowing while the merge prepares
                futs.append(srv.submit(Request("t", q)))
            assert all(f.result(60).ok for f in futs)
        assert m.merge_count >= 1  # stop() drains the in-flight merge
        snap = srv.stats.snapshot()
        assert snap["writes"]["merges"] == m.merge_count
        assert snap["writes"]["merge_ms_p95"] > 0


# ---------------------------------------------------------------------------
# Write epoch (the serve-layer result cache's invalidation signal)
# ---------------------------------------------------------------------------


class TestWriteEpoch:
    def test_bumps_per_applied_op_before_ack(self, base_indexes, ds):
        """Every applied write increments ``write_epoch`` synchronously —
        by the time ``upsert``/``delete`` returns (i.e. before any ack can
        resolve) the epoch already differs, so a cache entry recorded
        under the old epoch can never serve a post-write read."""
        m = MutableEngine(_engine(base_indexes, "none"))
        assert m.write_epoch == 0
        m.upsert(ds.features[N0], ds.attrs[N0], id=N0)
        assert m.write_epoch == 1
        assert m.delete(N0)
        assert m.write_epoch == 2
        # a rejected write (non-visible delete) applies nothing: no bump
        assert not m.delete(N0)
        assert m.write_epoch == 2

    def test_immutable_engine_epoch_is_constant_zero(self, base_indexes):
        assert _engine(base_indexes, "none").write_epoch == 0

    def test_wal_replay_advances_epoch(self, base_indexes, ds, tmp_path):
        """Recovered ops bump the epoch too — a cache surviving a restart
        (hypothetically) could only under-serve, never serve stale."""
        path = str(tmp_path / "wal.log")
        m = MutableEngine(_engine(base_indexes, "none"), wal_path=path)
        m.upsert(ds.features[N0], ds.attrs[N0], id=N0)
        m.upsert(ds.features[N0 + 1], ds.attrs[N0 + 1], id=N0 + 1)
        del m
        m2 = MutableEngine(_engine(base_indexes, "none"), wal_path=path)
        assert m2.write_epoch == 2
        assert m2.exists(N0) and m2.exists(N0 + 1)


# ---------------------------------------------------------------------------
# End-to-end freshness (the acceptance bar)
# ---------------------------------------------------------------------------


class TestFreshnessEndToEnd:
    def test_recall_bar_through_serve(self):
        ds = make_hybrid_dataset(
            n=3300, n_queries=64, profile="sift", attr_dim=5,
            labels_per_dim=3, n_clusters=16, attr_cluster_corr=0.6, seed=0,
        )
        eng = Engine.build(ds.features[:3000], ds.attrs[:3000],
                           HelpConfig(gamma=24, gamma_new=6, max_rounds=8))
        m = MutableEngine(eng, CompactionPolicy(max_delta_rows=10 ** 9))
        reg = TenantRegistry(default_policy=TenantPolicy(
            params=SearchParams(k=K, pool_size=POOL, pioneer_size=16)))

        rng = np.random.default_rng(7)
        deleted = sorted(int(x) for x in rng.choice(3000, 150,
                                                    replace=False))
        writes = [Upsert("t", ds.features[i], ds.attrs[i], id=i)
                  for i in range(3000, 3300)]
        writes += [Delete("t", i) for i in deleted]
        reads = [Request("t", Query(ds.query_features[i],
                                    [MATCH(int(v))
                                     for v in ds.query_attrs[i]]),
                         request_id=10_000 + i)
                 for i in range(64)]

        feats = ds.features.copy()
        feats[np.asarray(deleted)] = 1e6
        truth = brute_force_hybrid(feats, ds.attrs, ds.query_features,
                                   ds.query_attrs, K)

        def recall_of(responses):
            done = sorted((r for r in responses if hasattr(r, "ids")),
                          key=lambda r: r.request_id)
            assert len(done) == 64
            return recall_at_k(np.stack([r.ids for r in done]),
                               truth.ids, K)

        # writes then queries, all pre-merge (delta holds all 450 ops)
        out, _ = serve_loop(m, [(0.0, w) for w in writes]
                            + [(1.0, r) for r in reads], reg)
        assert all(r.ok for r in out)
        r_pre = recall_of(out)
        assert m.merge_count == 0 and m.delta.n_alive == 300

        # one more write trips the size trigger: the merge runs inside the
        # serving loop, then the same queries replay post-merge
        m.policy = CompactionPolicy(max_delta_rows=10)
        poke = Upsert("t", ds.features[3299], ds.attrs[3299], id=3299)
        out2, stats2 = serve_loop(
            m, [(0.0, poke)] + [(1.0, r) for r in reads], reg)
        assert all(r.ok for r in out2)
        assert m.merge_count == 1 and m.delta.n_alive <= 1
        r_post = recall_of(out2)
        assert stats2.snapshot()["writes"]["merges"] == 1

        assert r_pre >= 0.9, r_pre
        assert r_post >= 0.9, r_post
        # visibility stays exact post-merge
        assert not any(m.exists(i) for i in deleted)
        ids = np.asarray(m.search(
            QueryBatch.match(ds.features[deleted[:8]],
                             ds.attrs[deleted[:8]]),
            SearchParams(k=K, pool_size=POOL)).ids)
        assert not np.isin(ids, np.asarray(deleted)).any()


# ---------------------------------------------------------------------------
# Write-ahead log
# ---------------------------------------------------------------------------


class TestWriteAheadLog:
    """repro.mutable.wal: record encoding, torn-tail recovery, and the
    MutableEngine replay / checkpoint lifecycle."""

    def _wal(self, tmp_path, feat_dim=4, attr_dim=2):
        from repro.mutable.wal import WriteAheadLog

        return WriteAheadLog(str(tmp_path / "wal.log"), feat_dim, attr_dim)

    def test_append_replay_roundtrip(self, tmp_path):
        w = self._wal(tmp_path)
        v0 = np.arange(4, dtype=np.float32)
        a0 = np.array([1, 2], np.int32)
        w.append("upsert", 7, v0, a0)
        w.append("delete", 3)
        w.append("upsert", 8, v0 * 2, a0 + 1)
        ops = w.replay()
        assert [(k, i) for k, i, _, _ in ops] == [
            ("upsert", 7), ("delete", 3), ("upsert", 8)]
        np.testing.assert_array_equal(ops[0][2], v0)
        np.testing.assert_array_equal(ops[0][3], a0)
        assert ops[1][2] is None and ops[1][3] is None
        np.testing.assert_array_equal(ops[2][2], v0 * 2)
        w.close()

    def test_reopen_validates_header(self, tmp_path):
        from repro.mutable.wal import WriteAheadLog

        w = self._wal(tmp_path)
        w.append("delete", 1)
        w.close()
        # same dims reopen fine and see the record
        w2 = WriteAheadLog(str(tmp_path / "wal.log"), 4, 2)
        assert len(w2.replay()) == 1
        w2.close()
        with pytest.raises(ValueError, match="dims"):
            WriteAheadLog(str(tmp_path / "wal.log"), 5, 2)
        (tmp_path / "junk.log").write_bytes(b"not json\n")
        with pytest.raises(ValueError, match="bad header"):
            WriteAheadLog(str(tmp_path / "junk.log"), 4, 2)

    def test_torn_tail_truncated(self, tmp_path):
        w = self._wal(tmp_path)
        v = np.zeros(4, np.float32)
        a = np.zeros(2, np.int32)
        w.append("upsert", 1, v, a)
        w.append("upsert", 2, v, a)
        w.close()
        path = str(tmp_path / "wal.log")
        import os

        full = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(full - 5)  # crash mid-record
        w2 = self._wal(tmp_path)
        ops = w2.replay()
        assert [i for _, i, _, _ in ops] == [1]  # torn record dropped
        # the tail was truncated at a record boundary: appends resume clean
        w2.append("delete", 9)
        assert [(k, i) for k, i, _, _ in w2.replay()] == [
            ("upsert", 1), ("delete", 9)]
        w2.close()

    def test_shape_mismatch_raises(self, tmp_path):
        w = self._wal(tmp_path)
        with pytest.raises(ValueError, match="WAL dims"):
            w.append("upsert", 1, np.zeros(3, np.float32),
                     np.zeros(2, np.int32))
        with pytest.raises(ValueError, match="unknown op kind"):
            w.append("compact", 1)
        w.close()

    def test_reset_shrinks_to_tail(self, tmp_path):
        w = self._wal(tmp_path)
        v = np.ones(4, np.float32)
        a = np.ones(2, np.int32)
        for i in range(5):
            w.append("upsert", i, v, a)
        w.reset([("delete", 42, None, None)])
        ops = w.replay()
        assert [(k, i) for k, i, _, _ in ops] == [("delete", 42)]
        w.append("upsert", 43, v, a)
        assert len(w.replay()) == 2
        w.close()


class TestWalEngineLifecycle:
    def test_replay_reconstructs_state(self, base_indexes, ds, tmp_path):
        wal = str(tmp_path / "m.wal")
        m = MutableEngine(_engine(base_indexes, "none"),
                          CompactionPolicy(max_delta_rows=10 ** 9),
                          wal_path=wal)
        inserted, overwrites, deleted = _apply_script(m, ds)
        assert m.write_stats()["wal_bytes"] > 0
        # brute is fully deterministic — the measured cost model can
        # legitimately plan m and m2 differently under wall-clock noise
        sp = SearchParams(k=K, pool_size=POOL, backend="brute")
        ref = m.search(
            QueryBatch.match(ds.features[:16], ds.attrs[:16]), sp)

        # "crash": rebuild over the same frozen base + WAL, no merge ran
        m2 = MutableEngine(_engine(base_indexes, "none"),
                           CompactionPolicy(max_delta_rows=10 ** 9),
                           wal_path=wal)
        assert m2.n_items == m.n_items
        assert m2.tombstones == m.tombstones
        assert not any(m2.exists(i) for i in deleted)
        assert m2._next_id == m._next_id
        res = m2.search(
            QueryBatch.match(ds.features[:16], ds.attrs[:16]), sp)
        np.testing.assert_array_equal(np.asarray(res.ids),
                                      np.asarray(ref.ids))
        np.testing.assert_array_equal(np.asarray(res.sqdists),
                                      np.asarray(ref.sqdists))
        m.wal.close()
        m2.wal.close()

    def test_checkpoint_folds_and_resets(self, base_indexes, ds, tmp_path):
        wal = str(tmp_path / "c.wal")
        m = MutableEngine(_engine(base_indexes, "none"),
                          CompactionPolicy(max_delta_rows=10 ** 9),
                          wal_path=wal)
        inserted, overwrites, deleted = _apply_script(m, ds)
        grown = m.write_stats()["wal_bytes"]
        out = str(tmp_path / "ckpt")
        stats = m.checkpoint(out)
        assert stats is not None and stats["linked"] == 50
        assert m.delta.n_rows == 0 and not m.oplog
        # log shrank to the tombstone restatement (15 deletes ≪ 50 upserts)
        assert m.write_stats()["wal_bytes"] < grown
        sp = SearchParams(k=K, pool_size=POOL, backend="brute")
        ref = m.search(
            QueryBatch.match(ds.features[:16], ds.attrs[:16]), sp)

        # restart recovery = load checkpoint + replay the tombstone log
        m2 = MutableEngine(Engine.load(out), wal_path=wal)
        assert m2.n_items == m.n_items
        assert m2.tombstones == m.tombstones
        assert not any(m2.exists(i) for i in deleted)
        assert m2.delta.n_rows == 0
        res = m2.search(
            QueryBatch.match(ds.features[:16], ds.attrs[:16]), sp)
        np.testing.assert_array_equal(np.asarray(res.ids),
                                      np.asarray(ref.ids))
        m.wal.close()
        m2.wal.close()

    def test_merge_keeps_wal_replayable(self, base_indexes, ds, tmp_path):
        """merge() alone is an in-memory optimization — the WAL still
        holds every op, so replay over the *original* base reconstructs
        the same logical corpus."""
        wal = str(tmp_path / "g.wal")
        m = MutableEngine(_engine(base_indexes, "none"),
                          CompactionPolicy(max_delta_rows=10 ** 9),
                          wal_path=wal)
        _, _, deleted = _apply_script(m, ds)
        m.merge()
        logical = m.n_items
        m2 = MutableEngine(_engine(base_indexes, "none"),
                           CompactionPolicy(max_delta_rows=10 ** 9),
                           wal_path=wal)
        assert m2.n_items == logical
        assert not any(m2.exists(i) for i in deleted)
        m.wal.close()
        m2.wal.close()
