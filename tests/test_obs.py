"""Observability subsystem (repro.obs): registry thread-safety and bounds,
streaming-histogram percentile accuracy vs numpy, zero-allocation no-op
tracing, deterministic serve-loop trace decomposition, exporter formats,
the metrics HTTP endpoint, ServerStats snapshot compatibility, and
negative-result caching."""
import json
import re
import threading
import tracemalloc
import urllib.request

import numpy as np
import pytest

from repro.api import MATCH, Engine, Query, SearchParams
from repro.core.help_graph import HelpConfig
from repro.data.synthetic import make_hybrid_dataset
from repro.obs import (
    LATENCY_MS_BOUNDS, MetricsRegistry, MetricsServer, NOOP_SPAN, Tracer,
    chrome_trace, current, json_snapshot, log_bounds, prometheus_text,
)
from repro.obs import trace as obs_trace
from repro.serve import (
    Request, ServerStats, TenantPolicy, TenantRegistry, serve_loop,
)

HELP_CFG = HelpConfig(gamma=12, gamma_new=4, max_rounds=3,
                      quality_sample=64, node_block=512)
PARAMS = SearchParams(k=10, pool_size=32, pioneer_size=8)


@pytest.fixture(scope="module")
def ds():
    return make_hybrid_dataset(
        n=2000, n_queries=48, profile="sift", attr_dim=5, labels_per_dim=3,
        n_clusters=8, attr_cluster_corr=0.6, seed=0,
    )


@pytest.fixture(scope="module")
def engine(ds):
    return Engine.build(ds.features, ds.attrs, HELP_CFG)


def _trace(ds, n=48, spacing=2e-4):
    tenants = ("acme", "beta")
    return [
        (i * spacing,
         Request(tenants[i % 2],
                 Query(ds.query_features[i],
                       [MATCH(int(x)) for x in ds.query_attrs[i]])))
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_counter_conservation_under_threads(self):
        """8 threads hammering one counter + one histogram lose nothing."""
        reg = MetricsRegistry()
        c = reg.counter("ops")
        h = reg.histogram("lat_ms")
        per_thread, n_threads = 2000, 8

        def work():
            for i in range(per_thread):
                c.inc()
                h.observe(float(i % 50) + 0.1)

        threads = [threading.Thread(target=work) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert c.value == per_thread * n_threads
        assert h.count == per_thread * n_threads

    def test_get_or_create_is_idempotent_and_kind_checked(self):
        reg = MetricsRegistry()
        assert reg.counter("x") is reg.counter("x")
        with pytest.raises(ValueError):
            reg.gauge("x")

    def test_histogram_percentiles_match_numpy(self):
        """Streaming log-bucket percentiles land within one bucket width
        (≤ ~26% relative at 10 buckets/decade) of numpy's exact answer."""
        rng = np.random.default_rng(0)
        samples = np.exp(rng.normal(np.log(5.0), 1.0, size=20_000))
        h = MetricsRegistry().histogram("lat", bounds=LATENCY_MS_BOUNDS)
        for s in samples:
            h.observe(float(s))
        for q in (50, 90, 95, 99):
            exact = float(np.percentile(samples, q))
            est = h.percentile(q)
            assert abs(est - exact) / exact < 0.26, (q, est, exact)
        assert h.count == samples.size
        assert h.min == pytest.approx(samples.min())
        assert h.max == pytest.approx(samples.max())

    def test_histogram_state_is_bounded(self):
        """A million observations keep a fixed-size footprint: bucket
        counts + scalars, no per-sample storage (the old list bug)."""
        h = MetricsRegistry().histogram("lat")
        for i in range(100_000):
            h.observe(float(i % 977) + 0.5)
        snap = h.snapshot()
        assert snap["count"] == 100_000
        assert len(h.cumulative_buckets()) == len(LATENCY_MS_BOUNDS) + 1
        assert len(h._counts) == len(LATENCY_MS_BOUNDS) + 1  # fixed buckets

    def test_log_bounds_cover_range(self):
        b = log_bounds(1e-3, 6e4, per_decade=10)
        assert b[0] <= 1e-3 and b[-1] >= 6e4
        assert all(x < y for x, y in zip(b, b[1:]))

    def test_providers_flatten_and_survive_errors(self):
        reg = MetricsRegistry()
        reg.register_provider(
            "exec", lambda: {"hits": 3, "nested": {"a": 1.5, "flag": True}}
        )
        reg.register_provider("boom", lambda: 1 / 0)
        vals = reg.provider_values()
        assert vals["exec_hits"] == 3
        assert vals["exec_nested_a"] == 1.5
        assert vals["exec_nested_flag"] == 1
        assert not any(k.startswith("boom") for k in vals)
        reg.unregister_provider("exec")
        assert "exec_hits" not in reg.provider_values()


# ---------------------------------------------------------------------------
# Tracing
# ---------------------------------------------------------------------------


class TestTracer:
    def test_noop_path_allocates_nothing(self):
        """With no active trace, span() returns the falsy singleton and the
        instrumentation pattern allocates zero objects on the hot path."""
        assert current() is NOOP_SPAN
        assert obs_trace.span("anything") is NOOP_SPAN
        assert not NOOP_SPAN

        def hot():
            with obs_trace.span("plan") as sp:
                if sp:  # pragma: no cover - never taken untraced
                    sp.set("k", 1)

        hot()  # warm any lazy interning
        tracemalloc.start()
        before = tracemalloc.take_snapshot()
        for _ in range(1000):
            hot()
        after = tracemalloc.take_snapshot()
        tracemalloc.stop()
        leaked = sum(
            s.size_diff for s in after.compare_to(before, "filename")
            if s.size_diff > 0
        )
        # tracemalloc's own bookkeeping can show small noise; anything per-
        # iteration would be >= 1000 * minimal object size (~32kB)
        assert leaked < 16_000

    def test_disabled_tracer_never_samples(self):
        t = Tracer(sample_every=0)
        assert not t.enabled
        assert not any(t.should_sample() for _ in range(100))

    def test_sampling_is_deterministic(self):
        t = Tracer(sample_every=3)
        picks = [t.should_sample() for _ in range(9)]
        assert picks == [False, False, True] * 3  # fires on every Nth tick

    def test_span_stack_nesting_and_find(self):
        t = Tracer(sample_every=1)
        tr = t.start("request")
        with tr.root.span("batch") as b:
            assert current() is b
            with obs_trace.span("plan") as p:
                p.set("backend", "graph")
            assert current() is b
        assert current() is NOOP_SPAN
        t.finish(tr)
        plan = tr.root.find("plan")
        assert plan is not None and plan.attrs["backend"] == "graph"
        assert tr.root.duration >= plan.duration >= 0.0

    def test_trace_store_is_bounded(self):
        t = Tracer(sample_every=1, max_traces=4)
        for i in range(10):
            tr = t.start(f"r{i}")
            t.finish(tr)
        kept = t.traces()
        assert len(kept) == 4
        assert kept[-1].root.name == "r9"  # oldest dropped first


# ---------------------------------------------------------------------------
# Serve-loop trace decomposition (deterministic driver)
# ---------------------------------------------------------------------------


class TestServeTrace:
    def test_trace_tree_sums_to_end_to_end_latency(self, ds, engine):
        reg = TenantRegistry(default_policy=TenantPolicy(params=PARAMS))
        tracer = Tracer(sample_every=1)
        resp, stats = serve_loop(
            engine, _trace(ds), reg, window_ms=2.0, buckets=(1, 8, 32),
            tracer=tracer,
        )
        assert all(r.ok for r in resp)
        traces = tracer.traces()
        assert traces, "sample_every=1 must record every flushed batch"
        for tr in traces:
            root = tr.root
            queue, batch = root.find("queue"), root.find("batch")
            assert queue is not None and batch is not None
            # exact by construction: root pinned to queue + batch
            assert root.duration == pytest.approx(
                queue.duration + batch.duration, abs=1e-9
            )
            # engine spans attached under batch via the thread-local stack
            for name in ("assemble", "plan", "compile", "execute"):
                assert batch.find(name) is not None, name
            child_s = sum(c.duration for c in batch.children)
            assert child_s <= batch.duration + 1e-9
            assert child_s >= 0.5 * batch.duration
            # recorded latency attrs re-derive the root within tolerance
            attr_ms = root.attrs["queue_ms"] + root.attrs["service_ms"]
            total_ms = root.duration * 1e3
            assert abs(total_ms - attr_ms) <= max(1.0, 0.25 * total_ms)

    def test_untraced_run_records_nothing(self, ds, engine):
        reg = TenantRegistry(default_policy=TenantPolicy(params=PARAMS))
        tracer = Tracer(sample_every=0)
        resp, _ = serve_loop(
            engine, _trace(ds, n=16), reg, window_ms=2.0, buckets=(1, 8),
            tracer=tracer,
        )
        assert all(r.ok for r in resp)
        assert tracer.traces() == []


# ---------------------------------------------------------------------------
# Exporters + HTTP endpoint
# ---------------------------------------------------------------------------


def _filled_registry():
    reg = MetricsRegistry()
    reg.counter("reqs").inc(7)
    reg.gauge("depth").set(3.5)
    h = reg.histogram("lat_ms")
    for v in (0.5, 1.5, 12.0, 80.0):
        h.observe(v)
    reg.register_provider("exec", lambda: {"hits": 2, "rate": 0.5})
    return reg


class TestExport:
    def test_prometheus_text_parses(self):
        text = prometheus_text(_filled_registry())
        sample = re.compile(
            r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? -?[0-9+\-.eEinfa]+$"
        )
        lines = [l for l in text.splitlines() if l]
        assert any(l.startswith("# TYPE reqs counter") for l in lines)
        assert any(l.startswith("# TYPE lat_ms histogram") for l in lines)
        for l in lines:
            if not l.startswith("#"):
                assert sample.match(l), l
        # histogram buckets are cumulative and end at +Inf == count
        buckets = [l for l in lines if l.startswith("lat_ms_bucket")]
        counts = [float(l.split()[-1]) for l in buckets]
        assert counts == sorted(counts)
        assert 'le="+Inf"' in buckets[-1] and counts[-1] == 4
        assert any(l.startswith("lat_ms_count 4") for l in lines)
        assert any(l.startswith("exec_hits 2") for l in lines)

    def test_json_snapshot_round_trips(self):
        snap = json.loads(json_snapshot(_filled_registry()))
        assert snap["counters"]["reqs"] == 7
        assert snap["histograms"]["lat_ms"]["count"] == 4
        assert snap["providers"]["exec_rate"] == 0.5

    def test_chrome_trace_structure(self):
        t = Tracer(sample_every=1)
        tr = t.start("request")
        with tr.root.span("batch"):
            with obs_trace.span("plan") as p:
                p.set("backend", "graph")
        t.finish(tr)
        doc = chrome_trace(t.traces())
        events = doc["traceEvents"]
        assert {e["name"] for e in events} >= {"request", "batch", "plan"}
        for e in events:
            assert e["ph"] == "X" and e["dur"] >= 0
        plan = next(e for e in events if e["name"] == "plan")
        assert plan["args"]["backend"] == "graph"

    def test_metrics_server_scrape(self):
        reg = _filled_registry()
        with MetricsServer(reg, port=0) as srv:
            text = urllib.request.urlopen(
                srv.url + "/metrics", timeout=5
            ).read().decode()
            assert "reqs 7" in text
            snap = json.loads(urllib.request.urlopen(
                srv.url + "/metrics.json", timeout=5
            ).read().decode())
            assert snap["counters"]["reqs"] == 7
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(srv.url + "/nope", timeout=5)


# ---------------------------------------------------------------------------
# ServerStats as a registry view
# ---------------------------------------------------------------------------


class TestServerStatsRegistry:
    def test_snapshot_keys_backward_compatible(self, ds, engine):
        reg = TenantRegistry(default_policy=TenantPolicy(params=PARAMS))
        _, stats = serve_loop(engine, _trace(ds), reg, window_ms=2.0,
                              buckets=(1, 8, 32))
        snap = stats.snapshot()
        for key in ("submitted", "completed", "rejected", "latency_ms",
                    "queue_ms_p99", "service_ms_p99", "batches",
                    "batch_fill_ratio", "qps", "service_qps", "per_tenant",
                    "retraces", "jit_hit_rate", "plan_cache"):
            assert key in snap, key
        for p in ("p50", "p95", "p99", "mean"):
            assert snap["latency_ms"][p] >= 0.0
        assert snap["latency_ms"]["p50"] <= snap["latency_ms"]["p99"]

    def test_no_unbounded_latency_lists(self, ds, engine):
        """The old queue_ms/service_ms/... per-request lists are gone;
        latency state is the registry's fixed-bucket histograms."""
        stats = ServerStats(engine)
        for attr in ("queue_ms", "service_ms", "total_ms", "merge_ms"):
            assert not hasattr(stats, attr)
        for _ in range(1000):
            stats.record_completion("t", 1.0, 2.0)
        assert stats.registry.histogram("serve_total_ms").count == 1000

    def test_registry_sees_all_owners(self, ds, engine):
        reg = TenantRegistry(default_policy=TenantPolicy(params=PARAMS))
        _, stats = serve_loop(engine, _trace(ds), reg, window_ms=2.0,
                              buckets=(1, 8, 32))
        vals = stats.registry.provider_values()
        assert vals["serve_completed"] == stats.completed
        assert "executor_hits" in vals
        assert "routing_jit_traces" in vals
        text = prometheus_text(stats.registry)
        assert "serve_total_ms_bucket" in text
        assert "serve_completed" in text


# ---------------------------------------------------------------------------
# Negative-result caching
# ---------------------------------------------------------------------------


class TestNegativeCache:
    def test_empty_hits_counted(self):
        from repro.cache.results import ResultCache

        rc = ResultCache(max_entries=8)
        k_neg, k_pos = b"neg", b"pos"
        rc.insert(k_neg, np.full(10, -1, np.int32),
                  np.full(10, np.inf, np.float32), now=0.0, epoch=0)
        rc.insert(k_pos, np.arange(10, dtype=np.int32),
                  np.zeros(10, np.float32), now=0.0, epoch=0)
        assert rc.lookup(k_neg, now=0.1, epoch=0) is not None
        assert rc.lookup(k_neg, now=0.2, epoch=0) is not None
        assert rc.lookup(k_pos, now=0.3, epoch=0) is not None
        st = rc.stats()
        assert st["empty_hits"] == 2
        assert st["empty_entries"] == 1
        assert st["hits"] == 3
        rc.reset_counters()
        assert rc.stats()["empty_hits"] == 0

    def test_partial_invalid_row_is_not_empty(self):
        from repro.cache.results import ResultCache

        rc = ResultCache(max_entries=8)
        ids = np.array([3, 1, -1, -1], np.int32)
        rc.insert(b"k", ids, np.zeros(4, np.float32), now=0.0, epoch=0)
        rc.lookup(b"k", now=0.1, epoch=0)
        assert rc.stats()["empty_hits"] == 0
