"""Out-of-core IVF partition layer (repro.partition).

The load-bearing property: a partitioned engine probing every partition
with the brute sub-backend is **bit-identical** to the flat brute oracle —
per-partition top-k under lexicographic (score, global-id) order merges to
exactly the global top-k, across every codec and predicate kind. On top of
that: coarse-quantizer invariants, SegmentStore LRU residency under the row
cap, conservative summary pruning, planner/executor wiring, the
per-partition save/load layout, and the MutableEngine guard.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.api import (
    ANY, BETWEEN, MATCH, ONE_OF, Engine, Query, QueryBatch, SearchParams,
)
from repro.api import planner as planner_mod
from repro.core.help_graph import HelpConfig
from repro.data.synthetic import make_hybrid_dataset
from repro.mutable import MutableEngine
from repro.partition import (
    PartitionData, PartitionedStableIndex, SegmentStore, assign_partitions,
    is_partitioned_dir, row_bucket, train_coarse,
)
from repro.quant import QuantConfig

N, P, NQ, K = 900, 5, 10, 10
CFG = HelpConfig(gamma=6, gamma_new=3, max_rounds=4)
MODES = ("none", "sq8", "pq", "pq4", "opq-pq4")


@pytest.fixture(scope="module")
def ds():
    return make_hybrid_dataset(
        n=N, n_queries=NQ, profile="deep", attr_dim=3, labels_per_dim=3,
        n_clusters=8, attr_cluster_corr=0.6, seed=0,
    )


@pytest.fixture(scope="module")
def engines(ds):
    """(flat, partitioned) engine pair per codec over the same corpus."""
    out = {}
    for mode in MODES:
        qc = QuantConfig(mode=mode, pq_subspaces=8)
        out[mode] = (
            Engine.build(ds.features, ds.attrs, CFG, quant_cfg=qc),
            Engine.build_partitioned(
                ds.features, ds.attrs, n_partitions=P, help_cfg=CFG,
                quant_cfg=qc,
            ),
        )
    return out


def _batches(ds) -> dict:
    """One QueryBatch per predicate kind (shared across parity cases)."""
    qv, qa = ds.query_features, ds.query_attrs
    lab = int(ds.attrs.max()) + 1
    one_of = [
        Query(qv[i], [
            ONE_OF(int(qa[i, 0]), int(qa[i, 0] + 1) % lab),
            MATCH(int(qa[i, 1])), ANY,
        ])
        for i in range(qv.shape[0])
    ]
    between = [
        Query(qv[i], [
            BETWEEN(int(qa[i, 0]), min(int(qa[i, 0]) + 1, lab - 1)),
            ANY, MATCH(int(qa[i, 2])),
        ])
        for i in range(qv.shape[0])
    ]
    return {
        "match": QueryBatch.match(qv, qa),
        "match_subset": QueryBatch.match(qv, qa, active=[0]),
        "one_of": QueryBatch.from_queries(one_of),
        "between": QueryBatch.from_queries(between),
    }


def _assert_bit_equal(res, ref, ctx=""):
    np.testing.assert_array_equal(
        np.asarray(res.ids), np.asarray(ref.ids), err_msg=f"{ctx}: ids"
    )
    np.testing.assert_array_equal(
        np.asarray(res.dists), np.asarray(ref.dists), err_msg=f"{ctx}: dists"
    )
    np.testing.assert_array_equal(
        np.asarray(res.sqdists), np.asarray(ref.sqdists),
        err_msg=f"{ctx}: sqdists",
    )


# ---------------------------------------------------------------------------
# coarse quantizer
# ---------------------------------------------------------------------------


class TestCoarseQuantizer:
    def test_train_and_assign_cover_all_rows(self, ds):
        cq = train_coarse(ds.features, P, n_iters=8, seed=0)
        assert cq.centroids.shape == (P, ds.features.shape[1])
        assert np.isfinite(cq.centroids).all()
        assign = assign_partitions(ds.features, cq.centroids)
        assert assign.shape == (N,)
        assert assign.min() >= 0 and assign.max() < P
        # chunked assignment ≡ the one-shot scorer's argmin
        scores = np.asarray(cq.scores(ds.features))
        np.testing.assert_array_equal(assign, scores.argmin(axis=1))

    def test_scores_are_sq_centroid_dists(self, ds):
        cq = train_coarse(ds.features, P, n_iters=4, seed=1)
        got = np.asarray(cq.scores(ds.features[:7]))
        want = (
            (ds.features[:7, None, :] - cq.centroids[None, :, :]) ** 2
        ).sum(-1)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# SegmentStore residency
# ---------------------------------------------------------------------------


def _fake_loader(sizes: dict):
    def load(pid: int) -> PartitionData:
        n = sizes[pid]
        return PartitionData(
            features=np.zeros((n, 4), np.float32),
            attrs=np.zeros((n, 2), np.int32),
            graph=np.zeros((n, 0), np.int32),
            codes=None,
            row_ids=np.arange(n, dtype=np.int32),
        )

    return load


class TestSegmentStore:
    def test_row_bucket(self):
        assert row_bucket(0) == 256
        assert row_bucket(256) == 256
        assert row_bucket(257) == 512
        assert row_bucket(5000) == 8192
        assert row_bucket(10, bucket_min=8) == 16

    def test_lru_eviction_respects_cap(self):
        sizes = {i: 100 for i in range(4)}  # bucket 256 each
        store = SegmentStore(_fake_loader(sizes), cap_rows=512)
        store.get(0)
        store.get(1)
        assert store.resident_ids() == [0, 1]
        store.get(2)  # evicts 0 (LRU)
        assert store.resident_ids() == [1, 2]
        store.get(1)  # hit refreshes recency
        store.get(3)  # now 2 is LRU
        assert store.resident_ids() == [1, 3]
        st = store.stats()
        assert st["hits"] == 1 and st["loads"] == 4 and st["evictions"] == 2
        assert st["peak_resident_rows"] <= 512
        assert st["resident_rows"] == 512

    def test_evict_before_load_bounds_peak(self):
        sizes = {i: 200 for i in range(6)}
        store = SegmentStore(_fake_loader(sizes), cap_rows=768)
        for pid in range(6):
            store.get(pid)
        assert store.peak_resident_rows <= 768

    def test_oversized_partition_still_loads(self):
        store = SegmentStore(_fake_loader({0: 100, 1: 3000}), cap_rows=512)
        store.get(0)
        part = store.get(1)  # bucket 4096 > cap: evicts all, loads anyway
        assert part.n_real == 3000 and part.n_pad == 4096
        assert store.resident_ids() == [1]

    def test_padding_and_masks(self):
        store = SegmentStore(_fake_loader({0: 10}), cap_rows=4096)
        part = store.get(0)
        assert part.n_real == 10 and part.n_pad == 256
        rid = np.asarray(part.row_ids)
        assert (rid[:10] >= 0).all() and (rid[10:] == -1).all()

    def test_prefetch_double_buffer(self):
        """Staged loads are claimed by get (prefetch_hits); stale entries
        falling off the two-deep buffer count as wasted; residency/caps are
        charged only at install time."""
        sizes = {i: 100 for i in range(5)}
        store = SegmentStore(_fake_loader(sizes), cap_rows=4096)
        order = list(range(4))
        for i, pid in enumerate(order):
            if i + 1 < len(order):
                store.prefetch(order[i + 1])
            store.get(pid)
        st = store.stats()
        assert st["prefetch_hits"] == 3 and st["prefetch_wasted"] == 0
        assert st["loads"] == 4
        # never-claimed staging counts as wasted on drop/evict_all
        store.prefetch(4)
        store.evict_all()
        assert store.stats()["prefetch_wasted"] == 1
        # prefetch of a resident pid is a no-op
        store.get(0)
        store.prefetch(0)
        assert store.stats()["prefetch_hits"] == 3

    def test_prefetch_buffer_depth_two(self):
        store = SegmentStore(_fake_loader({i: 100 for i in range(4)}),
                             cap_rows=4096)
        for pid in range(4):  # no interleaved gets: oldest entries fall off
            store.prefetch(pid)
        st = store.stats()
        assert st["prefetch_wasted"] == 2
        assert store.get(3) is not None
        assert store.stats()["prefetch_hits"] == 1

    def test_reset_counters_keeps_residency(self):
        store = SegmentStore(_fake_loader({0: 100, 1: 100}), cap_rows=1024)
        store.get(0)
        store.get(1)
        store.reset_counters()
        st = store.stats()
        assert st["loads"] == 0 and st["peak_resident_rows"] == 512
        assert st["resident_rows"] == 512


# ---------------------------------------------------------------------------
# full-probe bit parity vs the flat brute oracle
# ---------------------------------------------------------------------------


class TestFullProbeParity:
    @pytest.mark.parametrize("mode", MODES)
    @pytest.mark.parametrize(
        "kind", ["match", "match_subset", "one_of", "between"]
    )
    def test_nprobe_p_bit_exact(self, engines, ds, mode, kind):
        flat, part = engines[mode]
        qb = _batches(ds)[kind]
        ref = flat.search(qb, SearchParams(k=K, backend="brute"))
        res = part.search(
            qb, SearchParams(k=K, nprobe=P, sub_backend="brute")
        )
        _assert_bit_equal(res, ref, f"{mode}/{kind}")

    def test_exact_eval_counters_match_full_scan(self, engines, ds):
        _, part = engines["none"]
        qb = _batches(ds)["match"]
        res = part.search(
            qb, SearchParams(k=K, nprobe=P, sub_backend="brute")
        )
        assert (np.asarray(res.n_dist_evals) == N).all()

    def test_pq_counter_conventions(self, engines, ds):
        flat, part = engines["pq"]
        qb = _batches(ds)["match"]
        ref = flat.search(qb, SearchParams(k=K, backend="brute"))
        res = part.search(
            qb, SearchParams(k=K, nprobe=P, sub_backend="brute")
        )
        # same pool-sized exact rerank, same full code scan
        np.testing.assert_array_equal(
            np.asarray(res.n_dist_evals), np.asarray(ref.n_dist_evals)
        )
        assert (np.asarray(res.n_code_evals) == N).all()


# ---------------------------------------------------------------------------
# pruning: conservative, never drops a survivor partition
# ---------------------------------------------------------------------------


class TestPruning:
    @pytest.mark.parametrize(
        "kind", ["match", "match_subset", "one_of", "between"]
    )
    def test_survivor_mask_covers_admissible_rows(self, engines, ds, kind):
        pidx = engines["none"][1].index
        qb = _batches(ds)[kind]
        ok = pidx.survivor_mask(qb, hard_all=True)  # (B, P)
        adm = np.asarray(qb.admissible(ds.attrs))  # (B, N) hard semantics
        assign = assign_partitions(ds.features, pidx.quantizer.centroids)
        for b in range(qb.batch_size):
            rows = np.where(adm[b])[0]
            needed = np.unique(assign[rows])
            assert ok[b, needed].all(), (
                f"query {b} pruned a partition holding admissible rows"
            )

    def test_soft_dims_not_pruned_under_traversal(self, engines, ds):
        pidx = engines["none"][1].index
        qb = _batches(ds)["match"]  # all-MATCH, soft unless hard_all
        ok = pidx.survivor_mask(qb, hard_all=False)
        assert ok.all()  # only empty partitions may drop, none here

    def test_probe_orders_by_centroid_score(self, engines, ds):
        pidx = engines["none"][1].index
        qb = _batches(ds)["match"]
        probes = pidx.probe(qb, nprobe=P, hard_all=False)
        scores = np.asarray(pidx.quantizer.scores(qb.vectors))
        np.testing.assert_array_equal(
            probes, np.argsort(scores, axis=1, kind="stable")
        )


# ---------------------------------------------------------------------------
# planner / executor wiring
# ---------------------------------------------------------------------------


class TestPlannerExecutor:
    def test_auto_plans_partitioned_with_sqrt_p_nprobe(self, engines, ds):
        _, part = engines["none"]
        plan = part.plan(_batches(ds)["match"], SearchParams(k=K))
        assert plan.backend == "partitioned"
        assert plan.nprobe == round(P ** 0.5)
        assert plan.sub_backend in ("graph", "brute")

    def test_explicit_nprobe_clamped(self, engines, ds):
        _, part = engines["none"]
        qb = _batches(ds)["match"]
        assert part.plan(qb, SearchParams(nprobe=3)).nprobe == 3
        assert part.plan(qb, SearchParams(nprobe=99)).nprobe == P

    def test_sub_backend_override(self, engines, ds):
        _, part = engines["none"]
        qb = _batches(ds)["match"]
        assert part.plan(
            qb, SearchParams(sub_backend="graph")
        ).sub_backend == "graph"
        plan = part.plan(qb, SearchParams(sub_backend="brute"))
        assert plan.sub_backend == "brute" and plan.routing_cfg is None

    def test_backend_validation(self, engines, ds):
        flat, part = engines["none"]
        qb = _batches(ds)["match"]
        with pytest.raises(ValueError, match="unavailable on a partitioned"):
            part.plan(qb, SearchParams(backend="graph"))
        with pytest.raises(ValueError, match="needs a partitioned index"):
            flat.plan(qb, SearchParams(backend="partitioned"))
        with pytest.raises(ValueError, match="unknown sub_backend"):
            SearchParams(sub_backend="bogus")

    def test_no_calibration_probe_on_partitioned(self, engines):
        _, part = engines["none"]
        before = planner_mod.calibration_count()
        part.cost_model  # default model, no traversal probe possible
        assert planner_mod.calibration_count() == before

    def test_signatures_keyed_by_nprobe_and_sub_backend(self, engines, ds):
        _, part = engines["none"]
        qb = _batches(ds)["match"]
        ex = part.executor
        base = ex.stats()["misses"]
        part.search(qb, SearchParams(k=K, nprobe=2, sub_backend="brute"))
        part.search(qb, SearchParams(k=K, nprobe=3, sub_backend="brute"))
        assert ex.stats()["misses"] == base + 2  # distinct signatures
        hits = ex.stats()["hits"]
        part.search(qb, SearchParams(k=K, nprobe=3, sub_backend="brute"))
        assert ex.stats()["hits"] == hits + 1  # repeat is a cache hit

    def test_graph_sub_backend_runs_with_residency(self, engines, ds):
        _, part = engines["none"]
        qb = _batches(ds)["match"]
        cap = max(
            row_bucket(int(r)) for r in part.index.summaries.n_rows
        ) * 2
        part.index.set_residency(cap)
        store = part.index.store
        res = part.search(
            qb, SearchParams(k=K, nprobe=P, sub_backend="graph",
                             pool_size=32)
        )
        assert np.asarray(res.ids).shape == (NQ, K)
        assert (np.asarray(res.ids)[:, 0] >= 0).all()
        assert store.peak_resident_rows <= cap
        part.index.set_residency(None)


# ---------------------------------------------------------------------------
# persistence: per-partition layout, mmap, residency plumb-through
# ---------------------------------------------------------------------------


class TestSaveLoad:
    @pytest.mark.parametrize("mode", MODES)
    def test_roundtrip_bit_exact(self, engines, ds, tmp_path, mode):
        _, part = engines[mode]
        path = str(tmp_path / f"pidx_{mode}")
        part.save(path)
        assert is_partitioned_dir(path)
        loaded = Engine.load(path)
        assert loaded.is_partitioned
        assert loaded.n_items == N
        assert loaded.index.n_partitions == P
        np.testing.assert_array_equal(
            loaded.index.summaries.n_rows, part.index.summaries.n_rows
        )
        qb = _batches(ds)["one_of"]
        for sub in ("brute", "graph"):
            ref = part.search(
                qb, SearchParams(k=K, nprobe=P, sub_backend=sub)
            )
            res = loaded.search(
                qb, SearchParams(k=K, nprobe=P, sub_backend=sub)
            )
            _assert_bit_equal(res, ref, f"load/{mode}/{sub}")

    def test_load_residency_cap_applies(self, engines, tmp_path):
        _, part = engines["none"]
        path = str(tmp_path / "pidx_cap")
        part.save(path)
        cap = max(
            row_bucket(int(r)) for r in part.index.summaries.n_rows
        )
        loaded = Engine.load(path, residency_rows=cap)
        assert loaded.index.store.cap_rows == cap

    def test_residency_rows_rejected_on_flat(self, engines, tmp_path):
        flat, _ = engines["none"]
        path = str(tmp_path / "flat")
        flat.save(path)
        with pytest.raises(ValueError, match="residency_rows"):
            Engine.load(path, residency_rows=1024)

    def test_flat_mmap_load_matches(self, engines, ds, tmp_path):
        flat, _ = engines["pq"]
        path = str(tmp_path / "flat_mmap")
        flat.save(path)
        a = Engine.load(path)
        b = Engine.load(path, mmap=True)
        np.testing.assert_array_equal(
            np.asarray(a.index.features), np.asarray(b.index.features)
        )
        np.testing.assert_array_equal(
            np.asarray(a.index.quant.codes), np.asarray(b.index.quant.codes)
        )
        qb = _batches(ds)["match"]
        _assert_bit_equal(
            b.search(qb, SearchParams(k=K)),
            a.search(qb, SearchParams(k=K)),
            "mmap",
        )


# ---------------------------------------------------------------------------
# residency bound during partial probes
# ---------------------------------------------------------------------------


class TestResidencyBound:
    def test_peak_bounded_across_probe_stream(self, ds):
        eng = Engine.build_partitioned(
            ds.features, ds.attrs, n_partitions=P, help_cfg=CFG,
        )
        buckets = [
            row_bucket(int(r)) for r in eng.index.summaries.n_rows
        ]
        cap = max(buckets) * 2
        eng.index.set_residency(cap)
        store = eng.index.store
        qb = _batches(ds)["match"]
        for np_ in (1, 2, 3, 2, 1):
            eng.search(
                qb, SearchParams(k=K, nprobe=np_, sub_backend="brute")
            )
        st = store.stats()
        assert st["peak_resident_rows"] <= cap
        assert st["evictions"] > 0  # the cap actually forced streaming


# ---------------------------------------------------------------------------
# mutability guard
# ---------------------------------------------------------------------------


class TestMutableGuard:
    def test_mutable_engine_rejects_partitioned(self, engines):
        _, part = engines["none"]
        with pytest.raises(ValueError, match="partitioned"):
            MutableEngine(part)
