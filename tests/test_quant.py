"""Quantized search subsystem: codec roundtrips, ADC kernel parity
(interpret mode), quantized index persistence, end-to-end recall."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import auto as auto_mod
from repro.core.auto import MetricConfig
from repro.core.baselines import brute_force_hybrid, recall_at_k
from repro.core.help_graph import HelpConfig
from repro.core.index import StableIndex
from repro.core.routing import RoutingConfig
from repro.data.synthetic import make_hybrid_dataset
from repro.kernels.adc_scan.adc_scan import adc_scan_scores
from repro.kernels.adc_scan.ref import adc_scan_ref
from repro.quant import (
    QuantConfig,
    QuantizedVectors,
    adc_gathered_sqdist,
    adc_lut,
    pq_decode,
    pq_encode,
    pq_train,
    sq8_decode,
    sq8_encode,
)


@pytest.fixture(scope="module")
def small_ds():
    return make_hybrid_dataset(
        n=4000, n_queries=32, profile="sift", attr_dim=5, labels_per_dim=3,
        n_clusters=16, attr_cluster_corr=0.6, seed=0,
    )


@pytest.fixture(scope="module")
def small_index(small_ds):
    return StableIndex.build(
        small_ds.features, small_ds.attrs,
        HelpConfig(gamma=16, gamma_new=4, max_rounds=4,
                   quality_sample=64, node_block=1024),
    )


class TestSQ8Codec:
    def test_roundtrip_error_bounded_by_step(self):
        rng = np.random.default_rng(0)
        x = (rng.normal(size=(512, 32)) * rng.uniform(0.1, 30, 32)).astype(
            np.float32
        )
        codes, params = sq8_encode(x)
        assert codes.dtype == jnp.int8
        dec = np.asarray(sq8_decode(codes, params))
        # affine rounding: per-dim error ≤ half a quantization step
        step = np.asarray(params.scale)
        assert (np.abs(dec - x) <= 0.5 * step[None, :] + 1e-6).all()

    def test_range_endpoints_exact(self):
        x = np.array([[0.0], [255.0]], np.float32)
        codes, params = sq8_encode(x)
        dec = np.asarray(sq8_decode(codes, params))
        np.testing.assert_allclose(dec[:, 0], [0.0, 255.0], atol=1e-4)


class TestPQCodec:
    def test_encode_shapes_and_reconstruction(self):
        rng = np.random.default_rng(1)
        x = rng.normal(size=(2000, 48)).astype(np.float32)
        cb = pq_train(x, n_subspaces=8, n_iters=8, n_samples=1000, seed=0)
        codes = pq_encode(x, cb)
        assert codes.shape == (2000, 8)
        assert int(codes.max()) < 256 and int(codes.min()) >= 0
        dec = np.asarray(pq_decode(codes, cb))
        assert dec.shape == x.shape
        # reconstruction must beat the trivial zero codebook by a wide margin
        rel_mse = np.mean((dec - x) ** 2) / np.mean(x**2)
        assert rel_mse < 0.5, rel_mse

    def test_ragged_dim_zero_padded(self):
        """M not divisible by S: padding dims must not perturb distances."""
        rng = np.random.default_rng(2)
        x = rng.normal(size=(500, 30)).astype(np.float32)  # 30 / 8 ragged
        cb = pq_train(x, n_subspaces=8, n_iters=5, n_samples=500, seed=0)
        codes = pq_encode(x, cb)
        q = rng.normal(size=(3, 30)).astype(np.float32)
        lut = adc_lut(q, cb)
        d_adc = np.asarray(
            adc_gathered_sqdist(lut, jnp.broadcast_to(codes[None], (3,) + codes.shape))
        )
        dec = np.asarray(pq_decode(codes, cb))
        d_exact = ((q[:, None, :] - dec[None]) ** 2).sum(-1)
        np.testing.assert_allclose(d_adc, d_exact, rtol=1e-4, atol=1e-3)


class TestADCScanKernel:
    @pytest.mark.parametrize("b,n,s,l", [
        (4, 300, 8, 5),          # ragged N, everything padded
        (8, 256, 16, 7),         # exact blocks
        (1, 1, 4, 1),            # degenerate
        (9, 513, 8, 3),          # ragged in B and N
    ])
    def test_matches_ref(self, b, n, s, l):
        rng = np.random.default_rng(n + s)
        lut = jnp.asarray(rng.uniform(0, 4, size=(b, s, 256)), jnp.float32)
        codes = jnp.asarray(rng.integers(0, 256, size=(n, s)), jnp.int32)
        qa = jnp.asarray(rng.integers(0, 4, size=(b, l)), jnp.int32)
        xa = jnp.asarray(rng.integers(0, 4, size=(n, l)), jnp.int32)
        got = adc_scan_scores(lut, codes, qa, xa, alpha=0.8, interpret=True)
        want = adc_scan_ref(lut, codes, qa, xa, alpha=0.8)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-5
        )

    def test_l2_mode_and_mask(self):
        rng = np.random.default_rng(3)
        lut = jnp.asarray(rng.uniform(0, 2, size=(5, 8, 256)), jnp.float32)
        codes = jnp.asarray(rng.integers(0, 256, size=(100, 8)), jnp.int32)
        qa = jnp.asarray(rng.integers(0, 3, size=(5, 4)), jnp.int32)
        xa = jnp.asarray(rng.integers(0, 3, size=(100, 4)), jnp.int32)
        mask = jnp.asarray(rng.integers(0, 2, size=(5, 4)), jnp.int32)
        for mode, m in (("l2", None), ("auto", mask)):
            got = adc_scan_scores(
                lut, codes, qa, xa, alpha=1.3, mode=mode, mask=m, interpret=True
            )
            want = adc_scan_ref(lut, codes, qa, xa, alpha=1.3, mode=mode, mask=m)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-5
            )

    def test_interval_targets_match_ref(self):
        """[lo, hi] interval targets through the fused ADC penalty: kernel
        == ref, degenerate intervals bit-exact to the point path."""
        rng = np.random.default_rng(7)
        b, n, s, l = 5, 300, 8, 4
        lut = jnp.asarray(rng.uniform(0, 4, size=(b, s, 256)), jnp.float32)
        codes = jnp.asarray(rng.integers(0, 256, size=(n, s)), jnp.int32)
        lo = jnp.asarray(rng.integers(0, 3, size=(b, l)), jnp.int32)
        iv = jnp.stack([lo, lo + 2], -1)
        xa = jnp.asarray(rng.integers(0, 5, size=(n, l)), jnp.int32)
        got = adc_scan_scores(lut, codes, iv, xa, alpha=0.8, interpret=True)
        want = adc_scan_ref(lut, codes, iv, xa, alpha=0.8)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-5
        )
        qa = jnp.asarray(rng.integers(0, 5, size=(b, l)), jnp.int32)
        deg = jnp.stack([qa, qa], -1)
        np.testing.assert_array_equal(
            np.asarray(adc_scan_scores(lut, codes, deg, xa, alpha=0.8,
                                       interpret=True)),
            np.asarray(adc_scan_scores(lut, codes, qa, xa, alpha=0.8,
                                       interpret=True)),
        )

    def test_consistent_with_exact_on_decoded_vectors(self):
        """ADC fused scores == exact fused scores of the reconstruction."""
        rng = np.random.default_rng(4)
        x = rng.normal(size=(400, 32)).astype(np.float32)
        cb = pq_train(x, n_subspaces=8, n_iters=8, n_samples=400, seed=0)
        codes = pq_encode(x, cb)
        dec = pq_decode(codes, cb)
        q = rng.normal(size=(6, 32)).astype(np.float32)
        qa = jnp.asarray(rng.integers(0, 3, size=(6, 5)), jnp.int32)
        xa = jnp.asarray(rng.integers(0, 3, size=(400, 5)), jnp.int32)
        lut = adc_lut(q, cb)
        got = adc_scan_scores(lut, codes, qa, xa, alpha=0.9, interpret=True)
        want = auto_mod.brute_fused_sqdist(
            jnp.asarray(q), qa, dec, xa, MetricConfig(mode="auto", alpha=0.9)
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-2
        )


class TestQuantizedIndex:
    @pytest.mark.parametrize("mode", ["sq8", "pq"])
    def test_save_load_roundtrip(self, small_ds, tmp_path, mode):
        idx = StableIndex.build(
            small_ds.features[:1000], small_ds.attrs[:1000],
            HelpConfig(gamma=12, gamma_new=4, max_rounds=2,
                       quality_sample=64, node_block=512),
            quant_cfg=QuantConfig(mode=mode, pq_subspaces=8, pq_train_iters=5),
        )
        path = os.path.join(tmp_path, f"idx_{mode}")
        idx.save(path)
        idx2 = StableIndex.load(path)
        assert idx2.quant is not None and idx2.quant.cfg.mode == mode
        np.testing.assert_array_equal(
            np.asarray(idx.quant.codes), np.asarray(idx2.quant.codes)
        )
        if mode == "sq8":
            np.testing.assert_allclose(
                np.asarray(idx.quant.sq_params.scale),
                np.asarray(idx2.quant.sq_params.scale),
            )
        else:
            np.testing.assert_allclose(
                np.asarray(idx.quant.codebook.centroids),
                np.asarray(idx2.quant.codebook.centroids),
            )
        # loaded index must search identically to the in-memory one
        r1 = idx.search(small_ds.query_features, small_ds.query_attrs, 10)
        r2 = idx2.search(small_ds.query_features, small_ds.query_attrs, 10)
        np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))

    def test_unquantized_save_load_unaffected(self, small_index, tmp_path):
        path = os.path.join(tmp_path, "idx_plain")
        small_index.save(path)
        idx2 = StableIndex.load(path)
        assert idx2.quant is None

    @pytest.mark.parametrize("mode", ["sq8", "pq"])
    def test_recall_within_3_points_and_fewer_fp_evals(self, small_ds,
                                                       small_index, mode):
        ds = small_ds
        truth = brute_force_hybrid(
            ds.features, ds.attrs, ds.query_features, ds.query_attrs, 10
        )
        cfg = RoutingConfig(k=10, pool_size=64, pioneer_size=8)
        exact = small_index.search(ds.query_features, ds.query_attrs, 10, cfg)
        r_exact = recall_at_k(exact.ids, truth.ids, 10)

        quant = QuantizedVectors.build(
            ds.features, QuantConfig(mode=mode, pq_subspaces=16)
        )
        idx_q = dataclasses.replace(small_index, quant=quant)
        qcfg = dataclasses.replace(cfg, quant_mode=mode)
        res = idx_q.search(ds.query_features, ds.query_attrs, 10, qcfg)
        r_quant = recall_at_k(res.ids, truth.ids, 10)

        assert r_quant >= r_exact - 0.03, (mode, r_exact, r_quant)
        assert res.n_dist_evals.shape == (ds.query_features.shape[0],)
        assert res.total_dist_evals < exact.total_dist_evals
        assert res.total_code_evals > 0
        assert exact.total_code_evals == 0

    def test_rerank_size_bounds_fp_evals(self, small_ds, small_index):
        quant = QuantizedVectors.build(small_ds.features, QuantConfig(mode="sq8"))
        idx_q = dataclasses.replace(small_index, quant=quant)
        nq = small_ds.query_features.shape[0]
        cfg = RoutingConfig(k=10, pool_size=64, pioneer_size=8,
                            quant_mode="sq8", rerank_size=16)
        res = idx_q.search(small_ds.query_features, small_ds.query_attrs, 10, cfg)
        assert (np.asarray(res.n_dist_evals) <= 16).all()
        assert res.total_dist_evals <= 16 * nq

    def test_bad_configs_rejected(self):
        with pytest.raises(ValueError):
            RoutingConfig(quant_mode="fp4")
        with pytest.raises(ValueError):
            RoutingConfig(k=10, pool_size=64, rerank_size=4)  # < k
        with pytest.raises(ValueError):
            QuantConfig(mode="int2")
