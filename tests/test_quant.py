"""Quantized search subsystem: codec roundtrips (SQ8 / PQ / packed 4-bit
PQ / OPQ rotation), codec meta versioning, quantized index persistence,
end-to-end recall. Kernel parity lives in tests/test_adc_scan.py (the CI
kernel-parity gate)."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import brute_force_hybrid, recall_at_k
from repro.core.help_graph import HelpConfig
from repro.core.index import StableIndex
from repro.core.routing import RoutingConfig
from repro.data.synthetic import make_hybrid_dataset
from repro.quant import (
    CODEC_VERSION,
    QuantConfig,
    QuantizedVectors,
    adc_gathered_sqdist,
    adc_lut,
    opq_reconstruct,
    opq_train,
    pack_nibbles,
    pq_decode,
    pq_encode,
    pq_train,
    rotate,
    sq8_decode,
    sq8_encode,
    unpack_nibbles,
)
from repro.quant.store import check_codec_spec, codec_spec

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis — deterministic fallback
    from _hypothesis_compat import given, settings, st


@pytest.fixture(scope="module")
def small_ds():
    return make_hybrid_dataset(
        n=4000, n_queries=32, profile="sift", attr_dim=5, labels_per_dim=3,
        n_clusters=16, attr_cluster_corr=0.6, seed=0,
    )


@pytest.fixture(scope="module")
def small_index(small_ds):
    return StableIndex.build(
        small_ds.features, small_ds.attrs,
        HelpConfig(gamma=16, gamma_new=4, max_rounds=4,
                   quality_sample=64, node_block=1024),
    )


class TestSQ8Codec:
    def test_roundtrip_error_bounded_by_step(self):
        rng = np.random.default_rng(0)
        x = (rng.normal(size=(512, 32)) * rng.uniform(0.1, 30, 32)).astype(
            np.float32
        )
        codes, params = sq8_encode(x)
        assert codes.dtype == jnp.int8
        dec = np.asarray(sq8_decode(codes, params))
        # affine rounding: per-dim error ≤ half a quantization step
        step = np.asarray(params.scale)
        assert (np.abs(dec - x) <= 0.5 * step[None, :] + 1e-6).all()

    def test_range_endpoints_exact(self):
        x = np.array([[0.0], [255.0]], np.float32)
        codes, params = sq8_encode(x)
        dec = np.asarray(sq8_decode(codes, params))
        np.testing.assert_allclose(dec[:, 0], [0.0, 255.0], atol=1e-4)


class TestPQCodec:
    def test_encode_shapes_and_reconstruction(self):
        rng = np.random.default_rng(1)
        x = rng.normal(size=(2000, 48)).astype(np.float32)
        cb = pq_train(x, n_subspaces=8, n_iters=8, n_samples=1000, seed=0)
        codes = pq_encode(x, cb)
        assert codes.shape == (2000, 8)
        assert int(codes.max()) < 256 and int(codes.min()) >= 0
        dec = np.asarray(pq_decode(codes, cb))
        assert dec.shape == x.shape
        # reconstruction must beat the trivial zero codebook by a wide margin
        rel_mse = np.mean((dec - x) ** 2) / np.mean(x**2)
        assert rel_mse < 0.5, rel_mse

    def test_ragged_dim_zero_padded(self):
        """M not divisible by S: padding dims must not perturb distances."""
        rng = np.random.default_rng(2)
        x = rng.normal(size=(500, 30)).astype(np.float32)  # 30 / 8 ragged
        cb = pq_train(x, n_subspaces=8, n_iters=5, n_samples=500, seed=0)
        codes = pq_encode(x, cb)
        q = rng.normal(size=(3, 30)).astype(np.float32)
        lut = adc_lut(q, cb)
        d_adc = np.asarray(
            adc_gathered_sqdist(lut, jnp.broadcast_to(codes[None], (3,) + codes.shape))
        )
        dec = np.asarray(pq_decode(codes, cb))
        d_exact = ((q[:, None, :] - dec[None]) ** 2).sum(-1)
        np.testing.assert_allclose(d_adc, d_exact, rtol=1e-4, atol=1e-3)


class TestQuantizedIndex:
    @pytest.mark.parametrize("mode", ["sq8", "pq", "pq4", "opq-pq4"])
    def test_save_load_roundtrip(self, small_ds, tmp_path, mode):
        idx = StableIndex.build(
            small_ds.features[:1000], small_ds.attrs[:1000],
            HelpConfig(gamma=12, gamma_new=4, max_rounds=2,
                       quality_sample=64, node_block=512),
            quant_cfg=QuantConfig(mode=mode, pq_subspaces=8, pq_train_iters=5,
                                  opq_iters=2),
        )
        path = os.path.join(tmp_path, f"idx_{mode}")
        idx.save(path)
        idx2 = StableIndex.load(path)
        assert idx2.quant is not None and idx2.quant.cfg.mode == mode
        np.testing.assert_array_equal(
            np.asarray(idx.quant.codes), np.asarray(idx2.quant.codes)
        )
        if mode == "sq8":
            np.testing.assert_allclose(
                np.asarray(idx.quant.sq_params.scale),
                np.asarray(idx2.quant.sq_params.scale),
            )
        else:
            np.testing.assert_allclose(
                np.asarray(idx.quant.codebook.centroids),
                np.asarray(idx2.quant.codebook.centroids),
            )
        if idx.quant.rotation is not None:
            np.testing.assert_array_equal(
                np.asarray(idx.quant.rotation), np.asarray(idx2.quant.rotation)
            )
        # loaded index must search identically to the in-memory one
        r1 = idx.search(small_ds.query_features, small_ds.query_attrs, 10)
        r2 = idx2.search(small_ds.query_features, small_ds.query_attrs, 10)
        np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))

    def test_unquantized_save_load_unaffected(self, small_index, tmp_path):
        path = os.path.join(tmp_path, "idx_plain")
        small_index.save(path)
        idx2 = StableIndex.load(path)
        assert idx2.quant is None

    @pytest.mark.parametrize("mode", ["sq8", "pq", "pq4", "opq-pq"])
    def test_recall_within_3_points_and_fewer_fp_evals(self, small_ds,
                                                       small_index, mode):
        ds = small_ds
        truth = brute_force_hybrid(
            ds.features, ds.attrs, ds.query_features, ds.query_attrs, 10
        )
        cfg = RoutingConfig(k=10, pool_size=64, pioneer_size=8)
        exact = small_index.search(ds.query_features, ds.query_attrs, 10, cfg)
        r_exact = recall_at_k(exact.ids, truth.ids, 10)

        quant = QuantizedVectors.build(
            ds.features, QuantConfig(mode=mode, pq_subspaces=16)
        )
        idx_q = dataclasses.replace(small_index, quant=quant)
        qcfg = dataclasses.replace(cfg, quant_mode=mode)
        res = idx_q.search(ds.query_features, ds.query_attrs, 10, qcfg)
        r_quant = recall_at_k(res.ids, truth.ids, 10)

        assert r_quant >= r_exact - 0.03, (mode, r_exact, r_quant)
        assert res.n_dist_evals.shape == (ds.query_features.shape[0],)
        assert res.total_dist_evals < exact.total_dist_evals
        assert res.total_code_evals > 0
        assert exact.total_code_evals == 0

    def test_rerank_size_bounds_fp_evals(self, small_ds, small_index):
        quant = QuantizedVectors.build(small_ds.features, QuantConfig(mode="sq8"))
        idx_q = dataclasses.replace(small_index, quant=quant)
        nq = small_ds.query_features.shape[0]
        cfg = RoutingConfig(k=10, pool_size=64, pioneer_size=8,
                            quant_mode="sq8", rerank_size=16)
        res = idx_q.search(small_ds.query_features, small_ds.query_attrs, 10, cfg)
        assert (np.asarray(res.n_dist_evals) <= 16).all()
        assert res.total_dist_evals <= 16 * nq

    def test_bad_configs_rejected(self):
        with pytest.raises(ValueError):
            RoutingConfig(quant_mode="fp4")
        with pytest.raises(ValueError):
            RoutingConfig(k=10, pool_size=64, rerank_size=4)  # < k
        with pytest.raises(ValueError):
            QuantConfig(mode="int2")


class TestNibblePacking:
    @given(st.integers(1, 40), st.integers(1, 33), st.integers(0, 10**6))
    @settings(max_examples=25, deadline=None)
    def test_pack_unpack_roundtrip(self, n, s, seed):
        """Property: unpack(pack(c)) == c for any S, including odd S where
        the last byte carries a zero pad nibble."""
        rng = np.random.default_rng(seed)
        codes = rng.integers(0, 16, size=(n, s))
        packed = pack_nibbles(jnp.asarray(codes, jnp.int32))
        assert packed.dtype == jnp.uint8
        assert packed.shape == (n, (s + 1) // 2)
        np.testing.assert_array_equal(
            np.asarray(unpack_nibbles(packed, s)), codes
        )
        if s % 2:  # pad nibble must be zero so a zero-padded LUT ignores it
            assert (np.asarray(packed)[:, -1] >> 4 == 0).all()

    def test_packed_halves_code_bytes(self):
        rng = np.random.default_rng(0)
        x = rng.normal(size=(600, 32)).astype(np.float32)
        q8 = QuantizedVectors.build(x, QuantConfig(mode="pq", pq_subspaces=8,
                                                   pq_train_iters=3))
        q4 = QuantizedVectors.build(x, QuantConfig(mode="pq4", pq_subspaces=8,
                                                   pq_train_iters=3))
        assert q8.codes.dtype == jnp.uint8 and q4.codes.dtype == jnp.uint8
        assert q4.code_bytes * 2 == q8.code_bytes


class TestOPQ:
    @pytest.fixture(scope="class")
    def correlated(self):
        """Low-rank + noise: the regime where a learned rotation pays."""
        rng = np.random.default_rng(5)
        lat = rng.normal(size=(2000, 16)).astype(np.float32)
        mix = rng.normal(size=(16, 64)).astype(np.float32)
        return lat @ mix + 0.05 * rng.normal(size=(2000, 64)).astype(np.float32)

    @pytest.fixture(scope="class")
    def trained(self, correlated):
        return opq_train(correlated, n_subspaces=8, n_centroids=16,
                         n_iters=5, opq_iters=3, n_samples=2000, seed=0)

    def test_rotation_orthogonal(self, trained):
        rot, _ = trained
        r = np.asarray(rot)
        np.testing.assert_allclose(r.T @ r, np.eye(r.shape[0]), atol=1e-4)

    def test_rotation_preserves_distances(self, correlated, trained):
        rot, _ = trained
        x, y = correlated[:64], correlated[64:128]
        d0 = np.linalg.norm(x - y, axis=1)
        d1 = np.linalg.norm(
            np.asarray(rotate(x, rot)) - np.asarray(rotate(y, rot)), axis=1
        )
        np.testing.assert_allclose(d0, d1, rtol=1e-4, atol=1e-3)

    def test_opq_reconstruction_beats_plain_pq(self, correlated, trained):
        x = correlated
        rot, cb = trained
        codes = pq_encode(rotate(x, rot), cb)
        rec = np.asarray(opq_reconstruct(codes, cb, rot, x.shape[1]))
        mse_opq = float(np.mean((rec - x) ** 2))
        cb0 = pq_train(x, n_subspaces=8, n_centroids=16, n_iters=5,
                       n_samples=2000, seed=0)
        dec0 = np.asarray(pq_decode(pq_encode(x, cb0), cb0))[:, : x.shape[1]]
        mse_pq = float(np.mean((dec0 - x) ** 2))
        assert mse_opq <= mse_pq, (mse_opq, mse_pq)


class TestCodecMeta:
    def _spec(self, mode):
        return codec_spec(QuantConfig(mode=mode, pq_subspaces=8))

    def test_spec_versions(self):
        assert self._spec("pq")["version"] == 1
        for mode in ("pq4", "opq-pq", "opq-pq4"):
            assert self._spec(mode)["version"] == CODEC_VERSION

    def test_future_version_rejected(self):
        spec = dict(self._spec("pq4"), version=CODEC_VERSION + 1)
        with pytest.raises(ValueError, match="version"):
            check_codec_spec(spec, QuantConfig(mode="pq4"))

    def test_v2_store_without_spec_rejected(self):
        """An old writer can't have produced packed/rotated codes — a v2
        mode with no codec block means a corrupt or hand-edited store."""
        with pytest.raises(ValueError, match="codec"):
            check_codec_spec(None, QuantConfig(mode="opq-pq4"))

    def test_mismatched_spec_rejected(self):
        with pytest.raises(ValueError):
            check_codec_spec(self._spec("pq"), QuantConfig(mode="pq4"))

    def test_old_reader_rejects_unknown_mode_string(self):
        # an old QuantConfig (this one) fails loudly on future mode names
        with pytest.raises(ValueError):
            QuantConfig(mode="opq-pq2")

    def test_saved_store_roundtrips_spec(self, tmp_path):
        rng = np.random.default_rng(9)
        x = rng.normal(size=(400, 32)).astype(np.float32)
        qv = QuantizedVectors.build(
            x, QuantConfig(mode="opq-pq4", pq_subspaces=8, pq_train_iters=3,
                           opq_iters=2)
        )
        meta = qv.save(str(tmp_path))
        assert meta["codec"] == codec_spec(qv.cfg)
        q2 = QuantizedVectors.load(str(tmp_path), meta)
        np.testing.assert_array_equal(np.asarray(qv.codes), np.asarray(q2.codes))
        np.testing.assert_array_equal(
            np.asarray(qv.rotation), np.asarray(q2.rotation)
        )
        meta_bad = dict(meta, codec=dict(meta["codec"], version=99))
        with pytest.raises(ValueError, match="version"):
            QuantizedVectors.load(str(tmp_path), meta_bad)
