"""Two-stage sharded top-k merge (EXPERIMENTS.md §Perf hillclimb 3):
exactness vs single-stage, across shard counts and metric modes."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis — deterministic fallback
    from _hypothesis_compat import given, settings, st

from repro.models.recsys import hybrid_retrieval_topk


def _case(seed, b=3, n=960, d=12, l=4):
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.normal(size=(b, d)), jnp.float32),
        jnp.asarray(rng.integers(0, 3, (b, l)), jnp.int32),
        jnp.asarray(rng.normal(size=(n, d)), jnp.float32),
        jnp.asarray(rng.integers(0, 3, (n, l)), jnp.int32),
    )


@pytest.mark.parametrize("shards", [1, 2, 4, 16])
@pytest.mark.parametrize("mode", ["auto", "l2"])
def test_two_stage_equals_single_stage(shards, mode):
    u, ua, e, ea = _case(0)
    d1, i1 = hybrid_retrieval_topk(u, ua, e, ea, k=10, alpha=0.8, mode=mode,
                                   topk_shards=1)
    d2, i2 = hybrid_retrieval_topk(u, ua, e, ea, k=10, alpha=0.8, mode=mode,
                                   topk_shards=shards)
    np.testing.assert_allclose(np.sort(np.asarray(d1), 1),
                               np.sort(np.asarray(d2), 1), rtol=1e-5)
    for r1, r2 in zip(np.asarray(i1), np.asarray(i2)):
        assert set(r1.tolist()) == set(r2.tolist())


@given(st.integers(0, 200))
@settings(max_examples=20, deadline=None)
def test_two_stage_property(seed):
    rng = np.random.default_rng(seed)
    k = int(rng.integers(1, 8))
    shards = int(rng.choice([2, 4, 8]))
    n = shards * int(rng.integers(8, 40))
    u, ua, e, ea = _case(seed, b=2, n=n)
    d1, i1 = hybrid_retrieval_topk(u, ua, e, ea, k=k, topk_shards=1)
    d2, i2 = hybrid_retrieval_topk(u, ua, e, ea, k=k, topk_shards=shards)
    np.testing.assert_allclose(np.sort(np.asarray(d1), 1),
                               np.sort(np.asarray(d2), 1), rtol=1e-5)


def test_non_divisible_falls_back_to_single_stage():
    u, ua, e, ea = _case(1, n=961)  # 961 % 16 != 0
    d, i = hybrid_retrieval_topk(u, ua, e, ea, k=5, topk_shards=16)
    d0, i0 = hybrid_retrieval_topk(u, ua, e, ea, k=5, topk_shards=1)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d0), rtol=1e-6)
