"""Multi-tenant serving subsystem: deterministic trace semantics (bit-exact
coalescing, zero re-traces after warmup, admission shedding), padded-batch
bit-exactness across codecs × predicate kinds, token-bucket determinism,
executor LRU bounds, and cost-model persistence (zero probes on load)."""
import dataclasses

import numpy as np
import pytest

from repro.api import (
    ANY, BETWEEN, MATCH, ONE_OF, Engine, Query, QueryBatch, SearchParams,
)
from repro.api import planner as planner_mod
from repro.core import routing as routing_mod
from repro.core.help_graph import HelpConfig
from repro.data.synthetic import make_hybrid_dataset
from repro.quant import QuantConfig
from repro.serve import (
    Microbatcher, Rejected, Request, ServerStats, TenantPolicy,
    TenantRegistry, ThreadedServer, TokenBucket, serve_loop,
)

HELP_CFG = HelpConfig(gamma=12, gamma_new=4, max_rounds=3,
                      quality_sample=64, node_block=512)
PARAMS = SearchParams(k=10, pool_size=32, pioneer_size=8)


@pytest.fixture(scope="module")
def ds():
    return make_hybrid_dataset(
        n=2000, n_queries=48, profile="sift", attr_dim=5, labels_per_dim=3,
        n_clusters=8, attr_cluster_corr=0.6, seed=0,
    )


@pytest.fixture(scope="module")
def engines(ds):
    out = {}
    for mode in ("none", "sq8", "pq"):
        out[mode] = Engine.build(
            ds.features, ds.attrs, HELP_CFG,
            quant_cfg=QuantConfig(mode=mode, pq_subspaces=8,
                                  pq_train_iters=4),
        )
    return out


def _query(ds, i: int, kind: str) -> Query:
    v, a = ds.query_features[i], ds.query_attrs[i]
    if kind == "match":
        return Query(v, [MATCH(int(x)) for x in a])
    if kind == "one_of":
        # alternate value-set widths: the ONE_OF `allowed` operand is
        # host-side only, so width must not affect signatures or traces
        sets = ONE_OF(0, 1) if i % 2 else ONE_OF(0, 1, 2)
        return Query(v, [MATCH(int(a[0])), ANY, sets,
                         MATCH(int(a[3])), ANY])
    assert kind == "between"
    return Query(v, [BETWEEN(0, 1), MATCH(int(a[1])), ANY, ANY,
                     MATCH(int(a[4]))])


def _mixed_trace(ds, n=48, spacing=2e-4, tenants=("acme", "beta")):
    kinds = ("match", "one_of", "between")
    return [
        (i * spacing,
         Request(tenants[i % len(tenants)], _query(ds, i, kinds[i % 3])))
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# The deterministic serving acceptance test
# ---------------------------------------------------------------------------


class TestServeLoopDeterministic:
    def test_trace_bit_identical_to_per_query_search(self, ds, engines):
        """Every coalesced, padded, bucketed response is bit-identical (ids
        and distances) to searching that query alone through Engine.search."""
        eng = engines["none"]
        reg = TenantRegistry(default_policy=TenantPolicy(params=PARAMS))
        trace = _mixed_trace(ds)
        resp, stats = serve_loop(eng, trace, reg, window_ms=2.0,
                                 buckets=(1, 8, 32))
        assert all(r.ok for r in resp)
        assert stats.batches > 0 and stats.completed == len(trace)
        for (_, req), r in zip(trace, resp):
            solo = eng.search(QueryBatch.from_queries([req.query]), PARAMS)
            np.testing.assert_array_equal(np.asarray(solo.ids[0]), r.ids)
            np.testing.assert_array_equal(np.asarray(solo.dists[0]), r.dists)

    def test_zero_retraces_after_warmup(self, ds, engines):
        """After one warmup pass, replaying the whole heterogeneous trace
        compiles nothing: every batch replays a cached executable."""
        eng = engines["none"]
        reg = TenantPolicy(params=PARAMS)
        trace = _mixed_trace(ds)
        serve_loop(eng, trace, TenantRegistry(default_policy=reg),
                   window_ms=2.0, buckets=(1, 8, 32))  # warmup
        t0 = routing_mod.trace_count()
        resp, stats = serve_loop(eng, trace,
                                 TenantRegistry(default_policy=reg),
                                 window_ms=2.0, buckets=(1, 8, 32))
        assert routing_mod.trace_count() == t0
        snap = stats.snapshot()
        assert snap["retraces"] == 0
        assert snap["plan_cache"]["misses"] == 0
        assert snap["plan_cache"]["hit_rate"] == 1.0
        assert all(r.ok for r in resp)

    def test_admission_sheds_over_budget_tenant(self, ds, engines):
        """A tenant exceeding its token budget is shed with a typed
        Rejected result; the co-tenant's stream is untouched."""
        eng = engines["none"]
        reg = TenantRegistry()
        reg.register("greedy", TenantPolicy(params=PARAMS, rate=10.0,
                                            burst=4.0))
        reg.register("modest", TenantPolicy(params=PARAMS))
        trace = [
            (i * 1e-3,
             Request("greedy" if i % 2 == 0 else "modest",
                     _query(ds, i, "match")))
            for i in range(32)
        ]
        resp, stats = serve_loop(eng, trace, reg, window_ms=2.0,
                                 buckets=(1, 8, 32))
        shed = [r for r in resp if not r.ok]
        assert shed and all(isinstance(r, Rejected) for r in shed)
        assert {r.tenant for r in shed} == {"greedy"}
        assert {r.reason for r in shed} == {"rate_limit"}
        # 16 greedy requests over 15ms at rate 10/s: burst 4 + ~0 refill
        assert 10 <= len(shed) <= 12
        snap = stats.snapshot()
        assert snap["per_tenant"]["modest"]["rejected"] == 0
        assert snap["per_tenant"]["modest"]["completed"] == 16
        assert snap["rejected_by_reason"]["rate_limit"] == len(shed)

    def test_trace_is_reproducible(self, ds, engines):
        eng = engines["none"]
        pol = TenantPolicy(params=PARAMS, rate=50.0, burst=8.0)
        trace = _mixed_trace(ds, n=32, spacing=1e-3)
        r1, _ = serve_loop(eng, trace, TenantRegistry(default_policy=pol),
                           window_ms=2.0, buckets=(1, 8))
        r2, _ = serve_loop(eng, trace, TenantRegistry(default_policy=pol),
                           window_ms=2.0, buckets=(1, 8))
        assert [type(a) for a in r1] == [type(b) for b in r2]
        for a, b in zip(r1, r2):
            if a.ok:
                np.testing.assert_array_equal(a.ids, b.ids)
                assert a.bucket == b.bucket


# ---------------------------------------------------------------------------
# Padded-batch bit-exactness across codecs × predicate kinds
# ---------------------------------------------------------------------------


class TestPaddedBatchBitExact:
    @pytest.mark.parametrize("codec", ["none", "sq8", "pq"])
    @pytest.mark.parametrize("kind", ["match", "one_of", "between"])
    def test_padded_bucket_matches_solo(self, ds, engines, codec, kind):
        """A coalesced batch padded up the bucket ladder returns bit-
        identical top-k (ids and distances) to each query searched alone."""
        eng = engines[codec]
        reqs = [Request("t", _query(ds, i, kind), request_id=i)
                for i in range(5)]  # 5 real rows → bucket 8 → 3 pad rows
        stats = ServerStats(eng)
        mb = Microbatcher(eng, stats, window_s=1.0, buckets=(8, 16))
        for r in reqs:
            assert mb.enqueue(r, PARAMS, now=0.0) == []
        out = {c.request_id: c for c in mb.flush_all(0.0)}
        assert len(out) == 5
        assert stats.batches == 1 and stats.bucket_rows == 8
        for r in reqs:
            solo = eng.search(QueryBatch.from_queries([r.query]), PARAMS)
            np.testing.assert_array_equal(
                np.asarray(solo.ids[0]), out[r.request_id].ids)
            np.testing.assert_array_equal(
                np.asarray(solo.dists[0]), out[r.request_id].dists)

    def test_mixed_kinds_split_groups(self, ds, engines):
        """Incompatible plan signatures never share a batch."""
        eng = engines["none"]
        stats = ServerStats(eng)
        mb = Microbatcher(eng, stats, window_s=1.0, buckets=(1, 8))
        for i, kind in enumerate(("match", "one_of", "between", "match")):
            mb.enqueue(Request("t", _query(ds, i, kind), request_id=i),
                       PARAMS, now=0.0)
        assert len(mb.queue.keys()) == 3
        out = mb.flush_all(0.0)
        assert len(out) == 4 and stats.batches == 3

    def test_full_bucket_flushes_eagerly(self, ds, engines):
        eng = engines["none"]
        mb = Microbatcher(eng, ServerStats(eng), window_s=1e9, buckets=(1, 4))
        flushed = []
        for i in range(4):
            flushed = mb.enqueue(
                Request("t", _query(ds, i, "match"), request_id=i),
                PARAMS, now=0.0,
            )
        assert len(flushed) == 4  # 4th request filled the largest bucket
        assert mb.queue.depth == 0
        assert flushed[0].bucket == 4 and flushed[0].batch_fill == 1.0

    def test_bucket_for_ladder(self, ds, engines):
        mb = Microbatcher(engines["none"], ServerStats(), window_s=1.0,
                          buckets=(32, 1, 8))  # unsorted on purpose
        assert mb.buckets == (1, 8, 32)
        assert [mb.bucket_for(n) for n in (1, 2, 8, 9, 32, 40)] == \
            [1, 8, 8, 32, 32, 32]


# ---------------------------------------------------------------------------
# Admission control details
# ---------------------------------------------------------------------------


class TestAdmission:
    def test_token_bucket_refill_is_deterministic(self):
        tb = TokenBucket(rate=10.0, burst=2.0)
        assert tb.try_take(0.0) and tb.try_take(0.0)
        assert not tb.try_take(0.0)  # burst exhausted
        assert not tb.try_take(0.05)  # 0.5 tokens refilled — still short
        assert tb.try_take(0.1)  # 1.0 token refilled
        assert not tb.try_take(0.09)  # clock never runs backwards

    def test_inf_rate_never_sheds_at_identical_timestamps(self, ds, engines):
        """rate=inf disables rate limiting entirely: a burst-sized pile of
        same-instant arrivals (a plain un-timestamped trace) all admit."""
        tb = TokenBucket(rate=float("inf"), burst=2.0)
        assert all(tb.try_take(0.0) for _ in range(10))
        eng = engines["none"]
        trace = [(0.0, Request("t", _query(ds, i % 48, "match")))
                 for i in range(40)]  # > default burst of 32, all at t=0
        resp, _ = serve_loop(
            eng, trace,
            TenantRegistry(default_policy=TenantPolicy(params=PARAMS)),
            window_ms=1.0, buckets=(1, 8, 64),
        )
        assert all(r.ok for r in resp)

    def test_duplicate_inflight_id_rejected(self, ds, engines):
        eng = engines["none"]
        trace = [
            (0.0, Request("t", _query(ds, 0, "match"), request_id=7)),
            (0.0, Request("t", _query(ds, 1, "match"), request_id=7)),
        ]
        resp, _ = serve_loop(
            eng, trace,
            TenantRegistry(default_policy=TenantPolicy(params=PARAMS)),
            window_ms=1.0, buckets=(1, 8),
        )
        assert resp[0].ok and not resp[1].ok
        assert resp[1].reason == "duplicate_id"

    def test_caps_and_unknown_tenant(self, ds, engines):
        eng = engines["none"]
        reg = TenantRegistry()
        reg.register("t", TenantPolicy(params=PARAMS, max_k=16,
                                       max_pool=64))
        mk = lambda **kw: Request("t", _query(ds, 0, "match"), **kw)
        trace = [
            (0.0, mk(params=dataclasses.replace(PARAMS, k=32))),  # k cap
            (0.0, mk(params=SearchParams(k=10, pool_size=128))),  # pool cap
            (0.0, Request("ghost", _query(ds, 0, "match"))),  # unknown
            (0.0, mk()),  # fine
        ]
        resp, stats = serve_loop(eng, trace, reg, window_ms=1.0,
                                 buckets=(1, 8))
        assert [getattr(r, "reason", None) for r in resp] == \
            ["k_cap", "pool_cap", "unknown_tenant", None]
        assert stats.completed == 1

    def test_queue_full_sheds(self, ds, engines):
        eng = engines["none"]
        trace = [(0.0, Request("t", _query(ds, i, "match")))
                 for i in range(6)]
        resp, _ = serve_loop(
            eng, trace, TenantRegistry(default_policy=TenantPolicy(
                params=PARAMS)),
            window_ms=1.0, buckets=(1, 32), max_queue=4,
        )
        reasons = [getattr(r, "reason", None) for r in resp]
        assert reasons[:4] == [None] * 4
        assert reasons[4:] == ["queue_full"] * 2

    def test_stats_snapshot_is_host_side(self, ds, engines):
        eng = engines["none"]
        resp, stats = serve_loop(
            eng, _mixed_trace(ds, n=12),
            TenantRegistry(default_policy=TenantPolicy(params=PARAMS)),
            window_ms=2.0, buckets=(1, 8),
        )
        snap = stats.snapshot()
        assert snap["completed"] == 12
        assert snap["latency_ms"]["p99"] >= snap["latency_ms"]["p50"] > 0
        assert 0 < snap["batch_fill_ratio"] <= 1.0
        assert snap["qps"] > 0 and snap["service_qps"] > 0
        assert set(snap["per_tenant"]) == {"acme", "beta"}


# ---------------------------------------------------------------------------
# Threaded front-end
# ---------------------------------------------------------------------------


def test_threaded_server_serves_and_reports(ds, engines):
    eng = engines["none"]
    reg = TenantRegistry(default_policy=TenantPolicy(params=PARAMS))
    reqs = [Request("t", _query(ds, i, "match")) for i in range(16)]
    with ThreadedServer(eng, reg, window_ms=2.0, buckets=(1, 8)) as srv:
        futs = [srv.submit(r) for r in reqs]
        out = [f.result(timeout=120) for f in futs]
    assert all(r.ok for r in out)
    for req, r in zip(reqs, out):
        solo = eng.search(QueryBatch.from_queries([req.query]), PARAMS)
        np.testing.assert_array_equal(np.asarray(solo.ids[0]), r.ids)
    snap = srv.stats.snapshot()
    assert snap["completed"] == 16 and snap["batches"] >= 2


def test_threaded_server_rejects_after_stop(ds, engines):
    srv = ThreadedServer(engines["none"],
                         TenantRegistry(default_policy=TenantPolicy(
                             params=PARAMS)),
                         window_ms=1.0, buckets=(1,))
    srv.start()
    srv.stop()
    r = srv.submit(Request("t", _query(ds, 0, "match"))).result(timeout=10)
    assert not r.ok and r.reason == "server_stopped"


# ---------------------------------------------------------------------------
# Executor LRU bound (serving produces many distinct signatures)
# ---------------------------------------------------------------------------


class TestExecutorBound:
    def test_eviction_and_recompile(self, ds, engines):
        eng = Engine(engines["none"].index, executor_max_entries=2)
        p = SearchParams(k=5, pool_size=32, backend="graph")
        qb = lambda b: QueryBatch.match(ds.query_features[:b],
                                        ds.query_attrs[:b])
        base = eng.search(qb(1), p)
        eng.search(qb(2), p)
        eng.search(qb(3), p)  # evicts the b=1 executable
        st = eng.executor.stats()
        assert st == {"hits": 0, "misses": 3, "evictions": 1, "size": 2,
                      "max_entries": 2}
        res = eng.search(qb(1), p)  # re-miss: recompiles correctly
        st = eng.executor.stats()
        assert st["misses"] == 4 and st["evictions"] == 2 and st["size"] == 2
        np.testing.assert_array_equal(np.asarray(base.ids),
                                      np.asarray(res.ids))
        eng.search(qb(1), p)
        assert eng.executor.stats()["hits"] == 1

    def test_bad_bound_rejected(self, ds, engines):
        from repro.api.executor import Executor

        with pytest.raises(ValueError, match="max_entries"):
            Executor(engines["none"], max_entries=0)


# ---------------------------------------------------------------------------
# Persisted cost-model calibration (load skips the probe)
# ---------------------------------------------------------------------------


class TestCostModelPersistence:
    def test_save_persists_and_load_skips_probe(self, ds, engines, tmp_path):
        eng = engines["none"]
        cm = eng.cost_model  # ensure calibrated (probe may run here)
        path = str(tmp_path / "idx")
        eng.save(path)
        n0 = planner_mod.calibration_count()
        t0 = routing_mod.trace_count()
        loaded = Engine.load(path)
        assert loaded.cost_model_override is not None
        # planning + searching uses the persisted model: zero probe
        # traversals on load or first use
        qb = QueryBatch.match(ds.query_features[:4], ds.query_attrs[:4])
        plan = loaded.plan(qb, SearchParams(k=10, pool_size=32))
        assert plan.cost_brute is not None
        assert planner_mod.calibration_count() == n0
        assert routing_mod.trace_count() == t0  # load itself never traces
        assert loaded.cost_model.to_json() == cm.to_json()

    def test_save_calibrates_once_when_lazy(self, ds, tmp_path):
        eng = Engine.build(ds.features, ds.attrs, HELP_CFG)
        assert eng._cost_model is None
        n0 = planner_mod.calibration_count()
        eng.save(str(tmp_path / "idx"))
        assert planner_mod.calibration_count() == n0 + 1  # probed at save
        n1 = planner_mod.calibration_count()
        Engine.load(str(tmp_path / "idx"))
        assert planner_mod.calibration_count() == n1

    def test_graphless_save_skips_cost_model(self, ds, tmp_path):
        eng = Engine.build(ds.features, ds.attrs, build_graph=False)
        n0 = planner_mod.calibration_count()
        path = str(tmp_path / "idx")
        eng.save(path)
        assert planner_mod.calibration_count() == n0  # nothing to calibrate
        loaded = Engine.load(path)
        assert loaded.cost_model_override is None
        res = loaded.search(
            QueryBatch.match(ds.query_features[:2], ds.query_attrs[:2]),
            SearchParams(k=5),
        )
        assert res.ids.shape == (2, 5)


# ---------------------------------------------------------------------------
# Serve-layer result cache at the submission surface
# ---------------------------------------------------------------------------


class TestResultCacheAtSubmission:
    """Driver-level cache semantics (the cache itself + the tiering engine
    are covered in tests/test_cache.py): key resolution happens on the
    *resolved* per-tenant params, rejections never touch the cache, and an
    uncached run reports no result_cache section."""

    def test_params_override_changes_cache_key(self, ds, engines):
        """The same query under a per-request params override must miss —
        the cache keys on the resolved SearchParams, not the query alone."""
        from repro.cache import ResultCache

        cache = ResultCache()
        reg = TenantRegistry(default_policy=TenantPolicy(
            params=PARAMS, max_k=10, max_pool=128))
        wide = dataclasses.replace(PARAMS, pool_size=64)
        q = _query(ds, 0, "match")
        trace = [(0.0, Request("a", q)),
                 (0.1, Request("a", q, params=wide)),
                 (0.2, Request("a", q)),
                 (0.3, Request("a", q, params=wide))]
        resp, stats = serve_loop(engines["none"], trace, reg, window_ms=1.0,
                                 buckets=(1,), result_cache=cache)
        assert [r.cached for r in resp] == [False, False, True, True]
        snap = stats.snapshot()
        assert snap["result_cache"]["hits"] == 2
        assert snap["result_cache"]["size"] == 2  # two distinct entries

    def test_rejected_requests_never_cached(self, ds, engines):
        from repro.cache import ResultCache

        cache = ResultCache()
        reg = TenantRegistry()
        reg.register("tight", TenantPolicy(params=PARAMS, rate=1e-9,
                                           burst=1.0))
        q = _query(ds, 0, "match")
        trace = [(0.0, Request("tight", q)), (0.0, Request("tight", q))]
        resp, stats = serve_loop(engines["none"], trace, reg, window_ms=1.0,
                                 buckets=(1,), result_cache=cache)
        assert resp[0].ok and not resp[1].ok  # burst=1 → second shed
        assert len(cache) == 1  # only the completed request was inserted
        assert stats.snapshot()["result_cache"]["served"] == 0

    def test_no_cache_means_no_section_and_false_flag(self, ds, engines):
        reg = TenantRegistry(default_policy=TenantPolicy(params=PARAMS))
        trace = _mixed_trace(ds, n=6)
        resp, stats = serve_loop(engines["none"], trace, reg, window_ms=1.0,
                                 buckets=(1, 8))
        assert all(not r.cached for r in resp)
        assert "result_cache" not in stats.snapshot()

    def test_threaded_server_repeat_hits(self, ds, engines):
        from repro.cache import ResultCache

        cache = ResultCache()
        reg = TenantRegistry(default_policy=TenantPolicy(params=PARAMS))
        q = _query(ds, 0, "match")
        with ThreadedServer(engines["none"], reg, window_ms=0.5,
                            buckets=(1, 8), result_cache=cache) as srv:
            r1 = srv.submit(Request("a", q)).result(30)
            r2 = srv.submit(Request("a", q)).result(30)
        assert not r1.cached and r2.cached
        np.testing.assert_array_equal(r1.ids, r2.ids)
        np.testing.assert_array_equal(r1.dists, r2.dists)
        assert srv.stats.snapshot()["result_cache"]["served"] == 1
